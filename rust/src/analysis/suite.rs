//! The lint suite: every built-in system program as a lintable target,
//! plus the fan-out driver that runs the pass framework over all of
//! them. This is what `magneton lint` invokes; CI gates the result on a
//! committed expected-findings manifest so the static rules provably
//! rediscover a declared subset of `cases/known.rs`.

use crate::cases;
use crate::coordinator::SysRun;
use crate::dispatch::Env;
use crate::energy::DeviceSpec;
use crate::exec::{Dispatcher, Program};
use crate::graph::{Graph, OpKind};
use crate::tensor::Tensor;
use crate::systems::frameworks::{
    build_conv, conv_params, tf_dispatcher, torch_dispatcher, ConvLayout, ConvSpec,
};
use crate::systems::imagegen::{
    build_unet_block, diffusers_dispatcher, sd_dispatcher, sd_env, sd_joint_dispatcher,
    UnetBuildOpts, UnetParams, UnetSpec,
};
use crate::systems::llm::{
    build_llm, default_env, hf_dispatcher, megatron_dispatcher, sglang_dispatcher,
    vllm_dispatcher, LlmBuildOpts, LlmSpec, TransformerParams,
};
use crate::systems::SystemId;
use crate::util::pool::par_map;
use crate::util::Prng;

use super::{lint_graph, LintContext, LintFinding};

/// One lintable system program.
pub struct LintTarget {
    /// Stable name used by the CLI `--target` filter and the manifest.
    pub name: String,
    /// Workload family for the static differential audit: only targets
    /// sharing a family implement the same workload and are comparable
    /// pairwise (`None` = single-system scenario, not diffable).
    pub family: Option<&'static str>,
    pub run: SysRun,
}

impl LintTarget {
    fn new(name: &str, family: Option<&'static str>, run: SysRun) -> LintTarget {
        LintTarget { name: name.to_string(), family, run }
    }
}

/// Every built-in program the lint suite covers: the four LLM serving
/// stacks (shared weights), both UNet builds, the torch/tf conv
/// routines, the wasteful sides of three known cases the static rules
/// are expected to rediscover (c2 redundant copy, c8 tf32 left off,
/// c9 redundant barrier), and a synthetic fixture exercising the
/// duplicate/idempotent/dead-feed rules with exact rewrites.
///
/// Targets sharing a `family` implement the same workload; the static
/// differential audit (`lint --diff`) compares exactly those pairs.
pub fn builtin_targets(seed: u64) -> Vec<LintTarget> {
    let mut out = Vec::new();
    let mut rng = Prng::new(seed);
    let params = TransformerParams::new(&mut rng, LlmSpec::gpt2_sim());
    let llm: [(SystemId, LlmBuildOpts, crate::exec::Dispatcher); 4] = [
        (SystemId::MiniHf, LlmBuildOpts::hf(), hf_dispatcher()),
        (SystemId::MiniVllm, LlmBuildOpts::vllm(), vllm_dispatcher()),
        (SystemId::MiniSglang, LlmBuildOpts::sglang(), sglang_dispatcher()),
        (SystemId::MiniMegatron, LlmBuildOpts::megatron(), megatron_dispatcher()),
    ];
    for (sys, opts, dispatcher) in llm {
        let prog = build_llm(&params, &opts);
        out.push(LintTarget::new(
            sys.name(),
            Some("llm"),
            SysRun::new(sys.name(), dispatcher, default_env(sys), prog),
        ));
    }
    let unet = UnetParams::new(&mut rng, UnetSpec::sd3_sim());
    out.push(LintTarget::new(
        SystemId::MiniSd.name(),
        Some("unet"),
        SysRun::new(
            SystemId::MiniSd.name(),
            sd_dispatcher(),
            sd_env(true),
            build_unet_block(&unet, &UnetBuildOpts::sd()),
        ),
    ));
    out.push(LintTarget::new(
        SystemId::MiniDiffusers.name(),
        Some("unet"),
        SysRun::new(
            SystemId::MiniDiffusers.name(),
            diffusers_dispatcher(),
            sd_env(true),
            build_unet_block(&unet, &UnetBuildOpts::diffusers()),
        ),
    ));
    let spec = ConvSpec::fig5c();
    let (x, w) = conv_params(&mut rng, spec);
    out.push(LintTarget::new(
        SystemId::MiniTorch.name(),
        Some("conv"),
        SysRun::new(
            SystemId::MiniTorch.name(),
            torch_dispatcher(),
            default_env(SystemId::MiniTorch),
            build_conv("torch", spec, ConvLayout::Nchw, &x, &w, "torch.conv2d"),
        ),
    ));
    out.push(LintTarget::new(
        SystemId::MiniTf.name(),
        Some("conv"),
        SysRun::new(
            SystemId::MiniTf.name(),
            tf_dispatcher(),
            default_env(SystemId::MiniTf),
            build_conv("tf", spec, ConvLayout::Nhwc, &x, &w, "tf.conv2d"),
        ),
    ));
    // c8's wasteful side is the same sd3_sim UNet with tf32 left off:
    // diffing it against mini-stable-diffusion rediscovers the case
    // statically, and the symbolic dispatch pass names the flag
    for (id, family) in [("c2", None), ("c8", Some("unet")), ("c9", None)] {
        let scenario = cases::by_id(id).expect("known case");
        let (wasteful, _clean) = (scenario.build)(&mut Prng::new(seed));
        out.push(LintTarget::new(&format!("case-{id}"), family, wasteful));
    }
    // c8's joint variant: the same UNet on a gemm routine where
    // `allow_tf32` only pays off together with `channels_last` — no
    // single-flag enumeration can reach the saving, the interaction
    // search (`lint --interact`) must. Not diffable against the `unet`
    // family (different kernel substrate), hence no family.
    out.push(LintTarget::new(
        "case-c8-joint",
        None,
        SysRun::new(
            "case-c8-joint",
            sd_joint_dispatcher(),
            Env::new(),
            build_unet_block(&unet, &UnetBuildOpts::sd()),
        ),
    ));
    out.push(lint_fixture(&mut rng));
    out
}

/// Synthetic target exercising the rules the fleet models are too
/// well-behaved to trigger: a duplicated branch whose bypass also kills
/// its exclusive input cone (`cse-duplicate` with a verifiable
/// rewrite), a double softmax (`idempotent-op`), and a weight feed
/// nothing consumes (`dead-weight`).
fn lint_fixture(rng: &mut Prng) -> LintTarget {
    let mut g = Graph::new("lint-fixture");
    let x = g.add(OpKind::Input, &[], "x");
    let w = g.add(OpKind::Weight, &[], "proj_w");
    let dead_w = g.add(OpKind::Weight, &[], "unused_bias");
    let m = g.add(OpKind::MatMul, &[x, w], "head.proj");
    let t1 = g.add(OpKind::Tanh, &[m], "head.branch1.tanh");
    let r1 = g.add(OpKind::Relu, &[t1], "head.branch1.relu");
    let t2 = g.add(OpKind::Tanh, &[m], "head.branch2.tanh");
    let r2 = g.add(OpKind::Relu, &[t2], "head.branch2.relu");
    let add = g.add(OpKind::Add, &[r1, r2], "head.combine");
    let s1 = g.add(OpKind::Softmax, &[add], "head.softmax");
    let s2 = g.add(OpKind::Softmax, &[s1], "head.resoftmax");
    g.add(OpKind::Output, &[s2], "out");
    let mut prog = Program::new(g);
    prog.feed(x, Tensor::randn(rng, &[64, 256]));
    prog.feed(w, Tensor::randn(rng, &[256, 128]));
    prog.feed(dead_w, Tensor::randn(rng, &[128]));
    LintTarget::new(
        "lint-fixture",
        None,
        SysRun::new("lint-fixture", Dispatcher::new(), Env::new(), prog),
    )
}

/// Lint result for one target.
pub struct TargetReport {
    pub name: String,
    /// Graph size (all nodes, including virtual ones).
    pub nodes: usize,
    /// Cost-model estimate of the whole program's energy (J).
    pub static_j: f64,
    /// Ranked findings (severity desc, then estimated waste desc).
    pub findings: Vec<LintFinding>,
    /// Set when the target's graph failed validation or shape inference.
    pub error: Option<String>,
    /// Joint-search diagnoses backing the `interaction` findings
    /// (populated only by `lint --interact` pseudo-targets; carries the
    /// per-flag marginal-vs-joint breakdown the renderer shows).
    pub interactions: Vec<super::interact::InteractionDiagnosis>,
}

/// Lint results across the whole suite.
pub struct LintReport {
    pub targets: Vec<TargetReport>,
    pub total_findings: usize,
    pub total_est_wasted_j: f64,
}

/// Run the default passes over every target, fanning out across
/// `threads` workers. Per-target results are independent and each
/// target's findings are fully ordered, so the report is
/// bit-identical for any worker count.
pub fn lint_suite(targets: &[LintTarget], device: &DeviceSpec, threads: usize) -> LintReport {
    let reports: Vec<TargetReport> = par_map(targets, threads, |t| {
        let cx = match LintContext::new(&t.run.prog, &t.run.dispatcher, &t.run.env, device) {
            Ok(cx) => cx,
            Err(e) => {
                return TargetReport {
                    name: t.name.clone(),
                    nodes: t.run.prog.graph.len(),
                    static_j: 0.0,
                    findings: vec![],
                    error: Some(e.to_string()),
                    interactions: vec![],
                }
            }
        };
        TargetReport {
            name: t.name.clone(),
            nodes: t.run.prog.graph.len(),
            static_j: cx.total_static_j(),
            findings: lint_graph(&cx),
            error: None,
            interactions: vec![],
        }
    });
    let total_findings = reports.iter().map(|r| r.findings.len()).sum();
    let total_est_wasted_j = reports
        .iter()
        .flat_map(|r| r.findings.iter())
        .map(|f| f.est_wasted_j)
        .sum();
    LintReport { targets: reports, total_findings, total_est_wasted_j }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtin_targets_are_unique_and_stable() {
        let t = builtin_targets(7);
        let names: Vec<&str> = t.iter().map(|t| t.name.as_str()).collect();
        assert_eq!(
            names,
            vec![
                "mini-hf-transformers",
                "mini-vllm",
                "mini-sglang",
                "mini-megatron",
                "mini-stable-diffusion",
                "mini-diffusers",
                "mini-pytorch",
                "mini-tensorflow",
                "case-c2",
                "case-c8",
                "case-c9",
                "case-c8-joint",
                "lint-fixture",
            ]
        );
    }

    #[test]
    fn families_group_comparable_workloads() {
        let t = builtin_targets(7);
        let family_of = |name: &str| {
            t.iter().find(|t| t.name == name).map(|t| t.family).expect("known target")
        };
        assert_eq!(family_of("mini-vllm"), Some("llm"));
        assert_eq!(family_of("mini-stable-diffusion"), Some("unet"));
        assert_eq!(family_of("case-c8"), Some("unet"));
        assert_eq!(family_of("mini-pytorch"), Some("conv"));
        assert_eq!(family_of("case-c9"), None);
        assert_eq!(family_of("case-c8-joint"), None);
        assert_eq!(family_of("lint-fixture"), None);
    }

    #[test]
    fn lint_fixture_triggers_the_new_rules() {
        let t = builtin_targets(7);
        let report = lint_suite(&t, &DeviceSpec::h200_sim(), 1);
        let fx = report.targets.iter().find(|t| t.name == "lint-fixture").unwrap();
        for rule in ["cse-duplicate", "idempotent-op", "dead-weight"] {
            assert!(
                fx.findings.iter().any(|f| f.rule == rule),
                "missing {rule}: {:?}",
                fx.findings.iter().map(|f| (f.rule, &f.label)).collect::<Vec<_>>()
            );
        }
    }

    #[test]
    fn suite_runs_clean_over_all_builtins() {
        let targets = builtin_targets(7);
        let report = lint_suite(&targets, &DeviceSpec::h200_sim(), 2);
        assert_eq!(report.targets.len(), targets.len());
        for t in &report.targets {
            assert!(t.error.is_none(), "{}: {:?}", t.name, t.error);
            assert!(t.static_j > 0.0, "{} has no static cost", t.name);
        }
        assert!(report.total_findings >= 5);
        assert!(report.total_est_wasted_j > 0.0);
    }

    #[test]
    fn megatron_gqa_expansion_is_rediscovered() {
        let targets = builtin_targets(7);
        let report = lint_suite(&targets, &DeviceSpec::h200_sim(), 1);
        let mg = report.targets.iter().find(|t| t.name == "mini-megatron").unwrap();
        assert!(
            mg.findings
                .iter()
                .any(|f| f.rule == "repeat-broadcast" && f.label.contains("repeat_interleave")),
            "megatron findings: {:?}",
            mg.findings.iter().map(|f| (f.rule, &f.label)).collect::<Vec<_>>()
        );
    }
}
