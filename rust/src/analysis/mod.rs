//! Static energy lint over the graph IR.
//!
//! The dynamic pipeline (exec → detect → diagnose) finds waste by
//! *running* two systems and diffing them; but each of the paper's three
//! root-cause classes — redundant operations, API misuse,
//! misconfiguration — leaves a statically visible signature in the
//! computation graph. This module finds those signatures before a single
//! joule is spent: a pass framework ([`LintPass`] over a [`LintContext`])
//! walks one graph with dominators, consumer lists, structural subtree
//! hashes, inferred shapes, and a per-node static cost derived from the
//! same dispatch + `counts::op_counts` + `KernelDesc::cost` path the
//! executor charges, so the estimate in every [`LintFinding`] is the
//! joule figure the executor *would* bill for the flagged nodes.
//!
//! Findings carry a mechanical rewrite ([`RewriteStep`]); `--verify`
//! applies it to a cloned program and drives the existing differential
//! pipeline to confirm the static prediction against a measured delta
//! (see [`rewrite::verify_finding`]). A config-lint layer
//! ([`lint_stream_config`] / [`lint_detect_config`]) covers the
//! misconfiguration class for the streaming/detect knobs that cannot be
//! seen in any graph.

pub mod diff;
pub mod interact;
pub mod rewrite;
pub mod rules;
pub mod suite;

use std::collections::BTreeMap;

use crate::detect::DetectConfig;
use crate::dispatch::Env;
use crate::energy::{DeviceSpec, KernelCost, KernelDesc};
use crate::exec::{counts, Dispatcher, Program};
use crate::fingerprint::{mix64, op_signature};
use crate::graph::dom::GraphDom;
use crate::graph::{Attrs, Graph, Node, NodeId, OpKind};
use crate::stream::StreamConfig;
use crate::tensor::Tensor;
use crate::Error;

pub use diff::{
    diff_name, diff_suite, diff_targets, StaticDiffConfig, StaticDiffReport,
};
pub use interact::{
    interact_name, interact_suite, interact_target, InteractConfig, InteractReport,
    InteractionDiagnosis, SearchStats,
};
pub use rewrite::{apply_rewrite, verify_finding, VerifyOutcome};
pub use rules::{default_passes, rule_names};
pub use suite::{builtin_targets, lint_suite, LintReport, LintTarget, TargetReport};

// ---------------------------------------------------------------------
// Findings
// ---------------------------------------------------------------------

/// How bad a finding is. `Error` is reserved for configurations that
/// break the tool itself (e.g. a stream window that can never close);
/// graph-level waste is `Warn`, fusion opportunities are `Info`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Severity {
    Info,
    Warn,
    Error,
}

impl Severity {
    pub fn name(&self) -> &'static str {
        match self {
            Severity::Info => "info",
            Severity::Warn => "warn",
            Severity::Error => "error",
        }
    }

    pub fn parse(s: &str) -> Option<Severity> {
        match s {
            "info" => Some(Severity::Info),
            "warn" => Some(Severity::Warn),
            "error" => Some(Severity::Error),
            _ => None,
        }
    }
}

/// One mechanical edit of a suggested rewrite. Steps are interpreted by
/// [`rewrite::apply_rewrite`], which rebuilds the graph rather than
/// mutating it (the executor charges every constructed node, dead or
/// not, so an unhooked node would still burn energy).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum RewriteStep {
    /// Delete `node`; its consumers read `replacement` instead.
    Bypass { node: NodeId, replacement: NodeId },
    /// Delete `node` (must have no surviving consumers).
    Remove { node: NodeId },
    /// Set an attribute on a surviving node.
    SetAttr { node: NodeId, key: String, value: String },
    /// Replace `add` with a fused `AddMm(bias, x, w)` and delete `mm`.
    FuseAddMm { mm: NodeId, add: NodeId },
}

/// One lint finding: a rule violation with the nodes involved, a static
/// estimate of the joules the executor would charge for them, and a
/// suggested rewrite.
#[derive(Clone, Debug, PartialEq)]
pub struct LintFinding {
    pub rule: &'static str,
    pub severity: Severity,
    /// Involved node ids, ascending (empty for config findings).
    pub nodes: Vec<NodeId>,
    /// Representative site label (or config key for config findings).
    pub label: String,
    /// Static estimate of wasted joules (0 for config findings).
    pub est_wasted_j: f64,
    pub suggestion: String,
    /// Mechanical rewrite; empty when the finding is advisory only.
    pub steps: Vec<RewriteStep>,
}

/// Rank findings: worst severity first, then largest estimate (total
/// order on the f64 bits, so the sort is deterministic), then stable
/// tie-breaks on rule/label/nodes.
pub fn sort_findings(findings: &mut [LintFinding]) {
    findings.sort_by(|a, b| {
        b.severity
            .cmp(&a.severity)
            .then(b.est_wasted_j.total_cmp(&a.est_wasted_j))
            .then(a.rule.cmp(b.rule))
            .then(a.label.cmp(&b.label))
            .then(a.nodes.cmp(&b.nodes))
    });
}

/// A lint rule: a pure function of the analysed graph.
pub trait LintPass {
    fn name(&self) -> &'static str;
    fn run(&self, cx: &LintContext) -> Vec<LintFinding>;
}

/// Run every default pass over one analysed graph and rank the results.
pub fn lint_graph(cx: &LintContext) -> Vec<LintFinding> {
    let mut out = Vec::new();
    for pass in default_passes() {
        out.extend(pass.run(cx));
    }
    sort_findings(&mut out);
    out
}

// ---------------------------------------------------------------------
// Context
// ---------------------------------------------------------------------

/// Everything a pass needs, computed once per graph: dominators, topo
/// order, consumer lists, structural subtree hashes, inferred shapes,
/// and the per-node static cost under the target's dispatcher + env +
/// device.
pub struct LintContext<'a> {
    pub prog: &'a Program,
    pub graph: &'a Graph,
    pub dispatcher: &'a Dispatcher,
    pub env: &'a Env,
    pub device: &'a DeviceSpec,
    pub dom: GraphDom,
    pub topo: Vec<NodeId>,
    pub consumers: Vec<Vec<NodeId>>,
    /// Structural subtree hash per node: leaves hash their identity,
    /// interior nodes fold op + attrs + ordered input hashes (labels are
    /// ignored for interior nodes, so renamed duplicates still collide).
    pub hashes: Vec<u64>,
    /// Inferred output shape per node; `None` when inference gave up
    /// (such nodes cost 0 and are skipped by shape-sensitive rules).
    pub shapes: Vec<Option<Vec<usize>>>,
    /// Static per-node cost (time/energy/power the executor would bill).
    pub cost: Vec<KernelCost>,
}

impl<'a> LintContext<'a> {
    /// Analyse `prog`. Fails (typed) on malformed graphs via
    /// [`Graph::validate`].
    pub fn new(
        prog: &'a Program,
        dispatcher: &'a Dispatcher,
        env: &'a Env,
        device: &'a DeviceSpec,
    ) -> crate::Result<LintContext<'a>> {
        let graph = &prog.graph;
        graph
            .validate()
            .map_err(|e| e.context(format!("lint: graph `{}`", graph.name)))?;
        let topo = graph.topo_order();
        let consumers = graph.consumers();
        let dom = GraphDom::analyze(graph);
        let hashes = structural_hashes(graph);
        let shapes = infer_shapes(graph, &prog.feeds);
        let mut cx = LintContext {
            prog,
            graph,
            dispatcher,
            env,
            device,
            dom,
            topo,
            consumers,
            hashes,
            shapes,
            cost: Vec::new(),
        };
        cx.cost = graph.nodes.iter().map(|n| cx.node_cost(n)).collect();
        Ok(cx)
    }

    /// Static energy estimate for one node (J).
    pub fn cost_j(&self, id: NodeId) -> f64 {
        self.cost[id].energy_j
    }

    pub fn node(&self, id: NodeId) -> &Node {
        &self.graph.nodes[id]
    }

    /// The static cost of one existing node: shapes in, executor's cost
    /// model out. Unknown shapes cost zero (never over-claim).
    fn node_cost(&self, node: &Node) -> KernelCost {
        let zero = KernelCost { time_us: 0.0, energy_j: 0.0, avg_power_w: 0.0 };
        if node.op.is_virtual() {
            return zero;
        }
        let out_shape = match &self.shapes[node.id] {
            Some(s) => s.clone(),
            None => return zero,
        };
        let mut in_shapes = Vec::with_capacity(node.inputs.len());
        for &i in &node.inputs {
            match &self.shapes[i] {
                Some(s) => in_shapes.push(s.clone()),
                None => return zero,
            }
        }
        self.op_cost(node.op, &node.attrs, &in_shapes, &out_shape)
    }

    /// Cost of a (possibly hypothetical) op application under this
    /// target's dispatcher/env/device. Mirrors the executor's
    /// `exec_kernel` cost path exactly: dispatch by the node's
    /// `dispatch` attr (falling back to the op name), count FLOPs/bytes
    /// with [`counts::op_counts`] on placeholder tensors, build the same
    /// [`KernelDesc`], and apply the same multi-launch adjustment.
    pub fn op_cost(
        &self,
        op: OpKind,
        attrs: &Attrs,
        in_shapes: &[Vec<usize>],
        out_shape: &[usize],
    ) -> KernelCost {
        let env = self.env.merged(attrs);
        let key = attrs.get("dispatch").cloned().unwrap_or_else(|| op.name().to_string());
        let outcome = self.dispatcher.dispatch(op, &key, &env);
        let choice = &outcome.choice;
        let ins: Vec<Tensor> = in_shapes.iter().map(|s| Tensor::zeros(s)).collect();
        let ins_ref: Vec<&Tensor> = ins.iter().collect();
        let out = Tensor::zeros(out_shape);
        let (flops, bytes, n_launches) = counts::op_counts(op, attrs, &ins_ref, &out);
        let desc = if op == OpKind::Barrier || op == OpKind::Idle {
            let wait_us = attr_f64(attrs, "wait_us", 1000.0);
            let frac = attr_f64(
                attrs,
                "power_frac",
                if op == OpKind::Barrier { 0.45 } else { 0.0 },
            );
            let w = if op == OpKind::Idle {
                self.device.idle_w
            } else {
                self.device.base_w.max(frac * self.device.max_w)
            };
            KernelDesc::fixed(&choice.kernel, wait_us, w)
        } else {
            KernelDesc {
                name: choice.kernel.clone(),
                unit: choice.unit,
                flops,
                bytes: bytes * choice.bytes_mult,
                efficiency: choice.efficiency,
                time_mult: choice.time_mult,
                fixed_time_us: 0.0,
                fixed_power_w: 0.0,
            }
        };
        let mut cost = desc.cost(self.device);
        if n_launches > 1 {
            let extra = (n_launches - 1) as f64 * self.device.launch_overhead_us;
            cost.time_us += extra;
            cost.energy_j += extra * 1e-6 * self.device.base_w;
            cost.avg_power_w = (cost.energy_j / (cost.time_us * 1e-6)).min(self.device.max_w);
            cost.energy_j = cost.energy_j.min(cost.avg_power_w * cost.time_us * 1e-6);
        }
        cost
    }

    /// Total static energy of the graph (J) — context for ranking.
    pub fn total_static_j(&self) -> f64 {
        self.cost.iter().map(|c| c.energy_j).sum()
    }
}

// ---------------------------------------------------------------------
// Structural hashes
// ---------------------------------------------------------------------

/// Subtree hash per node, reusing the fingerprint primitives: source
/// nodes (no inputs) hash their identity — two distinct `Input`s are
/// distinct values even under the same label — while interior nodes
/// fold op name, sorted attrs, and ordered input hashes, ignoring the
/// label so renamed duplicates still bucket together.
pub fn structural_hashes(g: &Graph) -> Vec<u64> {
    let mut hashes = vec![0u64; g.len()];
    for node in &g.nodes {
        let mut h = mix64(op_signature("", node.op.name()));
        for (k, v) in &node.attrs {
            h = mix64(h ^ op_signature(k, v));
        }
        if node.inputs.is_empty() {
            // leaf identity: the node id (bound to its feed)
            h = mix64(h ^ op_signature(&node.label, "leaf") ^ node.id as u64);
        }
        for &i in &node.inputs {
            h = mix64(h.rotate_left(7) ^ hashes[i]);
        }
        hashes[node.id] = h;
    }
    hashes
}

// ---------------------------------------------------------------------
// Shape inference
// ---------------------------------------------------------------------

pub(crate) fn attr_f64(attrs: &Attrs, k: &str, default: f64) -> f64 {
    attrs.get(k).and_then(|s| s.parse().ok()).unwrap_or(default)
}

pub(crate) fn attr_usize(attrs: &Attrs, k: &str, default: usize) -> usize {
    attrs.get(k).and_then(|s| s.parse().ok()).unwrap_or(default)
}

pub(crate) fn attr_csv(attrs: &Attrs, k: &str) -> Option<Vec<usize>> {
    attrs.get(k).map(|s| s.split(',').filter_map(|x| x.trim().parse().ok()).collect())
}

/// Right-aligned broadcast of two shapes (NumPy rules); `None` if
/// incompatible.
fn broadcast(a: &[usize], b: &[usize]) -> Option<Vec<usize>> {
    let rank = a.len().max(b.len());
    let mut out = Vec::with_capacity(rank);
    for i in 0..rank {
        let da = if i < rank - a.len() { 1 } else { a[i - (rank - a.len())] };
        let db = if i < rank - b.len() { 1 } else { b[i - (rank - b.len())] };
        if da != db && da != 1 && db != 1 {
            return None;
        }
        out.push(da.max(db));
    }
    Some(out)
}

/// Infer every node's output shape without evaluating any numerics
/// (`eval_node` would run seconds-slow composites like `Eigvals`).
/// Mirrors `exec::eval_node`'s shape semantics; ops it cannot handle
/// yield `None` and cost zero.
pub fn infer_shapes(g: &Graph, feeds: &BTreeMap<NodeId, Tensor>) -> Vec<Option<Vec<usize>>> {
    let mut shapes: Vec<Option<Vec<usize>>> = vec![None; g.len()];
    for node in &g.nodes {
        let ins: Vec<Option<&Vec<usize>>> =
            node.inputs.iter().map(|&i| shapes[i].as_ref()).collect();
        let first = ins.first().copied().flatten();
        let attrs = &node.attrs;
        shapes[node.id] = match node.op {
            OpKind::Input | OpKind::Weight => {
                feeds.get(&node.id).map(|t| t.shape().to_vec())
            }
            OpKind::MatMul => match (ins.first().copied().flatten(), ins.get(1).copied().flatten()) {
                (Some(a), Some(b)) if !a.is_empty() && !b.is_empty() => {
                    let mut s = a[..a.len() - 1].to_vec();
                    s.push(*b.last().unwrap());
                    Some(s)
                }
                _ => None,
            },
            OpKind::AddMm => match (ins.get(1).copied().flatten(), ins.get(2).copied().flatten()) {
                // inputs are [bias, x, w]
                (Some(x), Some(w)) if !x.is_empty() && !w.is_empty() => {
                    let mut s = x[..x.len() - 1].to_vec();
                    s.push(*w.last().unwrap());
                    Some(s)
                }
                _ => None,
            },
            OpKind::Add | OpKind::Sub | OpKind::Mul | OpKind::Div => {
                match (ins.first().copied().flatten(), ins.get(1).copied().flatten()) {
                    (Some(a), Some(b)) => broadcast(a, b),
                    _ => None,
                }
            }
            OpKind::Scale
            | OpKind::Pow
            | OpKind::Tanh
            | OpKind::Gelu
            | OpKind::Silu
            | OpKind::Relu
            | OpKind::Softmax
            | OpKind::LayerNorm
            | OpKind::RmsNorm
            | OpKind::Attention
            | OpKind::Contiguous
            | OpKind::Copy
            | OpKind::CumSum
            | OpKind::Sort
            | OpKind::Expm
            | OpKind::AllReduce
            | OpKind::Output => first.cloned(),
            OpKind::Barrier | OpKind::Idle => {
                first.cloned().or(Some(vec![1]))
            }
            OpKind::Permute => match (first, attr_csv(attrs, "perm")) {
                (Some(s), Some(perm)) if perm.len() == s.len() => {
                    Some(perm.iter().map(|&p| s[p]).collect())
                }
                _ => None,
            },
            OpKind::Reshape => attr_csv(attrs, "shape"),
            OpKind::Concat => {
                let dim = attr_usize(attrs, "dim", 0);
                let mut acc: Option<Vec<usize>> = None;
                let mut ok = !ins.is_empty();
                for s in &ins {
                    match (s, &mut acc) {
                        (Some(s), None) if dim < s.len() => acc = Some(s.to_vec()),
                        (Some(s), Some(a)) if s.len() == a.len() => a[dim] += s[dim],
                        _ => {
                            ok = false;
                            break;
                        }
                    }
                }
                if ok {
                    acc
                } else {
                    None
                }
            }
            OpKind::SplitChunk => {
                let dim = attr_usize(attrs, "dim", 0);
                let chunks = attr_usize(attrs, "chunks", 1).max(1);
                first.and_then(|s| {
                    if dim < s.len() && s[dim] % chunks == 0 {
                        let mut o = s.clone();
                        o[dim] /= chunks;
                        Some(o)
                    } else {
                        None
                    }
                })
            }
            OpKind::Slice => first.and_then(|s| {
                let dim = attr_usize(attrs, "dim", 0);
                if dim >= s.len() {
                    return None;
                }
                let start = attr_usize(attrs, "start", 0);
                let stop = attr_usize(attrs, "stop", s[dim]).min(s[dim]);
                if start > stop {
                    return None;
                }
                let mut o = s.clone();
                o[dim] = stop - start;
                Some(o)
            }),
            OpKind::TopK => first.and_then(|s| {
                let k = attr_usize(attrs, "k", 1);
                let mut o = s.clone();
                *o.last_mut()? = k;
                Some(o)
            }),
            OpKind::RepeatInterleave => first.and_then(|s| {
                let dim = attr_usize(attrs, "dim", 0);
                let reps = attr_usize(attrs, "reps", 1);
                if dim >= s.len() {
                    return None;
                }
                let mut o = s.clone();
                o[dim] *= reps;
                Some(o)
            }),
            OpKind::Embedding => match (first, attr_csv(attrs, "ids")) {
                (Some(table), Some(ids)) if !table.is_empty() => {
                    Some(vec![ids.len(), *table.last().unwrap()])
                }
                _ => None,
            },
            OpKind::Arange => Some(vec![attr_usize(attrs, "n", 1)]),
            OpKind::CrossEntropy | OpKind::CountNonzero => Some(vec![1]),
            OpKind::Eigvals => first.and_then(|s| s.first().map(|&n| vec![n])),
            OpKind::Conv2d => {
                conv2d_shape(first, ins.get(1).copied().flatten(), attrs)
            }
            // composite whose output geometry we don't model statically
            OpKind::Stft => None,
        };
    }
    shapes
}

fn conv2d_shape(
    x: Option<&Vec<usize>>,
    w: Option<&Vec<usize>>,
    attrs: &Attrs,
) -> Option<Vec<usize>> {
    let (x, w) = (x?, w?);
    if x.len() != 4 || w.len() != 4 {
        return None;
    }
    let pad = attr_usize(attrs, "pad", 1);
    let (co, kh, kw) = (w[0], w[2], w[3]);
    let nhwc = attrs.get("layout").map(String::as_str) == Some("nhwc");
    let (h, wdim) = if nhwc { (x[1], x[2]) } else { (x[2], x[3]) };
    let oh = (h + 2 * pad).checked_sub(kh)? + 1;
    let ow = (wdim + 2 * pad).checked_sub(kw)? + 1;
    Some(if nhwc { vec![x[0], oh, ow, co] } else { vec![x[0], co, oh, ow] })
}

// ---------------------------------------------------------------------
// Config lints (misconfiguration class: no graph to inspect)
// ---------------------------------------------------------------------

fn config_finding(severity: Severity, label: &str, suggestion: String) -> LintFinding {
    LintFinding {
        rule: "stream-config",
        severity,
        nodes: vec![],
        label: label.to_string(),
        est_wasted_j: 0.0,
        suggestion,
        steps: vec![],
    }
}

/// Foot-gun checks over a [`StreamConfig`] *before* an auditor is
/// constructed from it (the auditor asserts on some of these).
pub fn lint_stream_config(cfg: &StreamConfig) -> Vec<LintFinding> {
    let mut out = Vec::new();
    if cfg.window_ops == 0 {
        out.push(config_finding(
            Severity::Error,
            "window_ops",
            "window_ops is 0: no window can ever close; use a positive window".into(),
        ));
    }
    if cfg.hop_ops > cfg.window_ops {
        out.push(config_finding(
            Severity::Error,
            "hop_ops",
            format!(
                "hop_ops {} > window_ops {}: ops between windows are never audited (the \
                 auditor rejects this); set hop_ops <= window_ops",
                cfg.hop_ops, cfg.window_ops
            ),
        ));
    }
    if cfg.ring_cap == 0 {
        out.push(config_finding(
            Severity::Error,
            "ring_cap",
            "ring_cap is 0: no segment can be retained for matching".into(),
        ));
    } else if cfg.ring_cap < cfg.window_ops {
        out.push(config_finding(
            Severity::Warn,
            "ring_cap",
            format!(
                "ring_cap {} < window_ops {}: segments are evicted before their window \
                 closes, forcing spurious resyncs",
                cfg.ring_cap, cfg.window_ops
            ),
        ));
    }
    if cfg.resync_lookahead == 0 {
        out.push(config_finding(
            Severity::Warn,
            "resync_lookahead",
            "resync_lookahead is 0: a single dropped kernel desynchronises the stream \
             permanently; use a positive lookahead"
                .into(),
        ));
    }
    if cfg.resync_min_run == 0 {
        out.push(config_finding(
            Severity::Warn,
            "resync_min_run",
            "resync_min_run is 0: any accidental single-op agreement re-anchors the \
             stream; require a run of matching ops"
                .into(),
        ));
    }
    if cfg.content_eps <= 0.0 {
        out.push(config_finding(
            Severity::Warn,
            "content_eps",
            "content_eps <= 0 makes the content guard reject numerically identical \
             tensors under float noise"
                .into(),
        ));
    }
    out
}

/// Sanity checks over a [`DetectConfig`].
pub fn lint_detect_config(cfg: &DetectConfig) -> Vec<LintFinding> {
    let mut out = Vec::new();
    let mut cfg_finding = |severity, label: &str, suggestion: String| {
        out.push(LintFinding {
            rule: "detect-config",
            severity,
            nodes: vec![],
            label: label.to_string(),
            est_wasted_j: 0.0,
            suggestion,
            steps: vec![],
        });
    };
    if cfg.energy_threshold <= 0.0 || cfg.energy_threshold > 1.0 {
        cfg_finding(
            Severity::Error,
            "energy_threshold",
            format!(
                "energy_threshold {} is outside (0, 1]: every (or no) pair would be \
                 flagged regardless of waste",
                cfg.energy_threshold
            ),
        );
    }
    if cfg.perf_tolerance < 0.0 {
        cfg_finding(
            Severity::Error,
            "perf_tolerance",
            format!("perf_tolerance {} is negative", cfg.perf_tolerance),
        );
    }
    if cfg.output_tolerance <= 0.0 {
        cfg_finding(
            Severity::Warn,
            "output_tolerance",
            "output_tolerance <= 0 rejects numerically identical outputs under float \
             noise (tf32 vs fp32 pairs would never match)"
                .into(),
        );
    }
    out
}

// ---------------------------------------------------------------------
// Manifest (expected-findings gate for CI)
// ---------------------------------------------------------------------

/// One expected finding: `target rule label-substring` per line.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ExpectedFinding {
    pub target: String,
    pub rule: String,
    pub label_substr: String,
}

/// Parse an expected-findings manifest (`#` comments, blank lines ok).
pub fn parse_manifest(text: &str) -> crate::Result<Vec<ExpectedFinding>> {
    let mut out = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut it = line.split_whitespace();
        match (it.next(), it.next(), it.next()) {
            (Some(target), Some(rule), Some(substr)) => out.push(ExpectedFinding {
                target: target.to_string(),
                rule: rule.to_string(),
                label_substr: substr.to_string(),
            }),
            _ => {
                return Err(Error::msg(format!(
                    "manifest line {}: expected `target rule label-substring`, got `{line}`",
                    lineno + 1
                )))
            }
        }
    }
    Ok(out)
}

/// Partition a parsed manifest by pseudo-target tag: an entry whose
/// target carries a tagged prefix (`diff~`, `interact~`, ...) is kept
/// only while its producing layer is enabled, so a plain `lint --expect`
/// run neither fails on nor vacuously requires findings that only exist
/// behind `--diff`/`--interact`. Untagged entries always survive.
/// (Generalises the old `diff~`-only special case, under which new
/// tagged families silently failed plain-run gating.)
pub fn gate_manifest(
    entries: Vec<ExpectedFinding>,
    gates: &[(&str, bool)],
) -> Vec<ExpectedFinding> {
    entries
        .into_iter()
        .filter(|e| gates.iter().all(|(prefix, on)| *on || !e.target.starts_with(prefix)))
        .collect()
}

/// Check a lint report against a manifest; returns the unmet entries.
pub fn check_manifest(report: &LintReport, expected: &[ExpectedFinding]) -> Vec<ExpectedFinding> {
    expected
        .iter()
        .filter(|e| {
            !report.targets.iter().any(|t| {
                t.name == e.target
                    && t.findings
                        .iter()
                        .any(|f| f.rule == e.rule && f.label.contains(&e.label_substr))
            })
        })
        .cloned()
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::energy::DeviceSpec;

    fn ctx_parts() -> (Dispatcher, Env, DeviceSpec) {
        (Dispatcher::new(), Env::new(), DeviceSpec::h200_sim())
    }

    fn simple_prog() -> Program {
        let mut g = Graph::new("t");
        let x = g.add(OpKind::Input, &[], "x");
        let w = g.add(OpKind::Weight, &[], "w");
        let m = g.add(OpKind::MatMul, &[x, w], "proj");
        g.add(OpKind::Output, &[m], "out");
        let mut p = Program::new(g);
        p.feed(0, Tensor::zeros(&[8, 16]));
        p.feed(1, Tensor::zeros(&[16, 4]));
        p
    }

    #[test]
    fn shapes_follow_matmul() {
        let p = simple_prog();
        let shapes = infer_shapes(&p.graph, &p.feeds);
        assert_eq!(shapes[2], Some(vec![8, 4]));
        assert_eq!(shapes[3], Some(vec![8, 4]));
    }

    #[test]
    fn static_cost_matches_executor_cost_model() {
        let p = simple_prog();
        let (d, e, dev) = ctx_parts();
        let cx = LintContext::new(&p, &d, &e, &dev).unwrap();
        // the matmul must carry a positive static cost; virtual nodes none
        assert!(cx.cost_j(2) > 0.0);
        assert_eq!(cx.cost_j(0), 0.0);
        assert_eq!(cx.cost_j(3), 0.0);
        // and the executor bills the same energy for the same node
        let exec = crate::exec::Executor::new(dev.clone(), Dispatcher::new(), Env::new());
        let run = exec.run(&p);
        let billed = run.node_energy_j(2);
        assert!(
            (cx.cost_j(2) - billed).abs() < 1e-12 * billed.max(1.0),
            "static {} vs executor {}",
            cx.cost_j(2),
            billed
        );
    }

    #[test]
    fn broadcast_rules() {
        assert_eq!(broadcast(&[4, 8], &[8]), Some(vec![4, 8]));
        assert_eq!(broadcast(&[4, 1], &[4, 8]), Some(vec![4, 8]));
        assert_eq!(broadcast(&[3], &[4]), None);
    }

    #[test]
    fn structural_hashes_merge_renamed_duplicates() {
        let mut g = Graph::new("h");
        let x = g.add(OpKind::Input, &[], "x");
        let a = g.add(OpKind::Gelu, &[x], "first");
        let b = g.add(OpKind::Gelu, &[x], "second");
        let y = g.add(OpKind::Input, &[], "y");
        let c = g.add(OpKind::Gelu, &[y], "third");
        let h = structural_hashes(&g);
        assert_eq!(h[a], h[b], "same op on same input must collide");
        assert_ne!(h[a], h[c], "same op on a different source must differ");
    }

    #[test]
    fn stream_config_foot_guns() {
        let good = StreamConfig::default();
        assert!(lint_stream_config(&good).is_empty());
        let bad = StreamConfig {
            hop_ops: good.window_ops + 1,
            resync_lookahead: 0,
            ..StreamConfig::default()
        };
        let findings = lint_stream_config(&bad);
        assert!(findings.iter().any(|f| f.label == "hop_ops" && f.severity == Severity::Error));
        assert!(findings
            .iter()
            .any(|f| f.label == "resync_lookahead" && f.severity == Severity::Warn));
    }

    #[test]
    fn detect_config_threshold_range() {
        assert!(lint_detect_config(&DetectConfig::default()).is_empty());
        let bad = DetectConfig { energy_threshold: 0.0, ..DetectConfig::default() };
        let f = lint_detect_config(&bad);
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].severity, Severity::Error);
    }

    #[test]
    fn manifest_roundtrip_and_check() {
        let text = "# comment\nmini-vllm unfused-matmul-add qkv_proj\n\ncase-c9 redundant-sync barrier\n";
        let m = parse_manifest(text).unwrap();
        assert_eq!(m.len(), 2);
        assert!(parse_manifest("just two").is_err());
        let empty = LintReport { targets: vec![], total_findings: 0, total_est_wasted_j: 0.0 };
        assert_eq!(check_manifest(&empty, &m).len(), 2);
    }

    #[test]
    fn severity_orders_and_parses() {
        assert!(Severity::Error > Severity::Warn && Severity::Warn > Severity::Info);
        assert_eq!(Severity::parse("warn"), Some(Severity::Warn));
        assert_eq!(Severity::parse("nope"), None);
    }

    #[test]
    fn sort_is_severity_then_estimate() {
        let f = |rule: &'static str, sev, est| LintFinding {
            rule,
            severity: sev,
            nodes: vec![],
            label: rule.into(),
            est_wasted_j: est,
            suggestion: String::new(),
            steps: vec![],
        };
        let mut v = vec![
            f("small-warn", Severity::Warn, 0.1),
            f("big-info", Severity::Info, 5.0),
            f("big-warn", Severity::Warn, 2.0),
        ];
        sort_findings(&mut v);
        let order: Vec<&str> = v.iter().map(|x| x.rule).collect();
        assert_eq!(order, vec!["big-warn", "small-warn", "big-info"]);
    }
}
