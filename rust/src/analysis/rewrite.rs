//! Mechanical graph rewrites and the measure-after-fix loop.
//!
//! A lint finding's [`RewriteStep`]s describe the fix abstractly;
//! [`apply_rewrite`] performs it on a clone of the program, and
//! [`verify_finding`] closes the paper's measure-optimize-remeasure loop
//! by running the original and the rewritten program through the
//! existing differential pipeline ([`Magneton::audit`]) and comparing
//! the measured energy delta against the static estimate.

use std::collections::{BTreeMap, BTreeSet};

use crate::coordinator::{Magneton, SysRun};
use crate::energy::DeviceSpec;
use crate::exec::Program;
use crate::graph::{Attrs, Graph, NodeId, OpKind};
use crate::Error;

use super::{LintFinding, RewriteStep};

/// Apply `steps` to a clone of `prog`, rebuilding the graph so removed
/// nodes are physically absent (the executor bills every constructed
/// node, so merely disconnecting one would not save its energy).
///
/// Fails if a step drops a node something still consumes, or if
/// bypass replacements form a cycle.
pub fn apply_rewrite(prog: &Program, steps: &[RewriteStep]) -> crate::Result<Program> {
    let g = &prog.graph;
    let mut replace: BTreeMap<NodeId, NodeId> = BTreeMap::new();
    let mut removed: BTreeSet<NodeId> = BTreeSet::new();
    let mut set_attrs: Vec<(NodeId, &str, &str)> = Vec::new();
    // add-node id → matmul id it absorbs
    let mut fused: BTreeMap<NodeId, NodeId> = BTreeMap::new();
    for step in steps {
        match step {
            RewriteStep::Bypass { node, replacement } => {
                replace.insert(*node, *replacement);
                removed.insert(*node);
            }
            RewriteStep::Remove { node } => {
                removed.insert(*node);
            }
            RewriteStep::SetAttr { node, key, value } => {
                set_attrs.push((*node, key, value));
            }
            RewriteStep::FuseAddMm { mm, add } => {
                removed.insert(*mm);
                fused.insert(*add, *mm);
            }
        }
    }
    for &node in fused.keys() {
        let n = &g.nodes[node];
        if n.op != OpKind::Add || n.inputs.len() != 2 {
            return Err(Error::msg(format!(
                "fuse-addmm target `{}` is not a two-input add",
                n.label
            )));
        }
    }
    // follow bypass chains to the surviving producer
    let resolve = |mut id: NodeId| -> crate::Result<NodeId> {
        let mut hops = 0usize;
        while let Some(&r) = replace.get(&id) {
            id = r;
            hops += 1;
            if hops > replace.len() {
                return Err(Error::msg("rewrite replacement chain forms a cycle"));
            }
        }
        Ok(id)
    };
    let mut map: BTreeMap<NodeId, NodeId> = BTreeMap::new();
    let mut out = Graph::new(&format!("{}+lint-fix", g.name));
    for node in &g.nodes {
        if removed.contains(&node.id) {
            continue;
        }
        let remap = |inputs: &[NodeId]| -> crate::Result<Vec<NodeId>> {
            inputs
                .iter()
                .map(|&i| {
                    let r = resolve(i)?;
                    map.get(&r).copied().ok_or_else(|| {
                        Error::msg(format!(
                            "rewrite drops node {r} (`{}`) still consumed by `{}`",
                            g.nodes[r].label, node.label
                        ))
                    })
                })
                .collect()
        };
        let (op, inputs, mut attrs) = match fused.get(&node.id) {
            Some(&mm_id) => {
                let mm = &g.nodes[mm_id];
                let bias = node
                    .inputs
                    .iter()
                    .copied()
                    .find(|&i| i != mm_id)
                    .expect("validated two-input add");
                // AddMm input order is [bias, x, w]
                (OpKind::AddMm, remap(&[bias, mm.inputs[0], mm.inputs[1]])?, Attrs::new())
            }
            None => (node.op, remap(&node.inputs)?, node.attrs.clone()),
        };
        for &(id, key, value) in &set_attrs {
            if id == node.id {
                attrs.insert(key.to_string(), value.to_string());
            }
        }
        let new_id = out.add_attrs(op, &inputs, &node.label, attrs);
        map.insert(node.id, new_id);
    }
    let mut fixed = Program::new(out);
    for (&old, tensor) in &prog.feeds {
        if let Some(&new_id) = map.get(&old) {
            fixed.feed(new_id, tensor.clone());
        }
    }
    Ok(fixed)
}

/// What [`verify_finding`] measured.
#[derive(Clone, Debug)]
pub struct VerifyOutcome {
    /// Label of the system the finding came from.
    pub target: String,
    /// Site label of the finding.
    pub label: String,
    /// Rule that produced the finding.
    pub rule: &'static str,
    /// Static cost-model estimate of the waste (J).
    pub est_wasted_j: f64,
    /// Measured `before − after` energy (J); positive = the fix saves.
    pub measured_delta_j: f64,
    pub energy_before_j: f64,
    pub energy_after_j: f64,
    /// Static estimate and measured delta agree on direction.
    pub same_sign: bool,
    /// The differential detector itself flagged the before/after pair.
    pub detected: bool,
}

/// Apply a finding's rewrite and A/B the original vs fixed program
/// through the full differential pipeline, confirming (or refuting) the
/// static prediction with a measured energy delta.
pub fn verify_finding(
    run: &SysRun,
    finding: &LintFinding,
    device: &DeviceSpec,
) -> crate::Result<VerifyOutcome> {
    if finding.steps.is_empty() {
        return Err(Error::msg(format!(
            "finding `{}` at `{}` is advisory (no mechanical rewrite to verify)",
            finding.rule, finding.label
        )));
    }
    let rewritten = apply_rewrite(&run.prog, &finding.steps)
        .map_err(|e| e.context(format!("verify `{}` at `{}`", finding.rule, finding.label)))?;
    let fixed = SysRun::new(
        &format!("{} (lint fix: {})", run.label, finding.rule),
        run.dispatcher.clone(),
        run.env.clone(),
        rewritten,
    );
    let outcome = Magneton::new(device.clone()).audit(run, &fixed);
    let before = outcome.a.total_energy_j;
    let after = outcome.b.total_energy_j;
    let measured = before - after;
    Ok(VerifyOutcome {
        target: run.label.clone(),
        label: finding.label.clone(),
        rule: finding.rule,
        est_wasted_j: finding.est_wasted_j,
        measured_delta_j: measured,
        energy_before_j: before,
        energy_after_j: after,
        same_sign: (measured > 0.0) == (finding.est_wasted_j > 0.0),
        detected: outcome.detected(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dispatch::Env;
    use crate::exec::{Dispatcher, Executor};
    use crate::tensor::Tensor;

    fn exec(prog: &Program) -> crate::exec::RunArtifacts {
        Executor::new(DeviceSpec::h200_sim(), Dispatcher::new(), Env::new()).run(prog)
    }

    #[test]
    fn bypass_rewires_and_removes() {
        let mut g = Graph::new("t");
        let x = g.add(OpKind::Input, &[], "x");
        let c = g.add(OpKind::Copy, &[x], "staging_copy");
        let s = g.add_attr1(OpKind::Scale, &[c], "halve", "s", "0.5");
        g.add(OpKind::Output, &[s], "out");
        let mut p = Program::new(g);
        p.feed(x, Tensor::randn(&mut crate::util::Prng::new(1), &[16, 16]));
        let fixed =
            apply_rewrite(&p, &[RewriteStep::Bypass { node: c, replacement: x }]).unwrap();
        assert_eq!(fixed.graph.len(), 3, "copy must be physically gone");
        assert!(fixed.graph.nodes.iter().all(|n| n.op != OpKind::Copy));
        // outputs unchanged, energy strictly lower
        let (before, after) = (exec(&p), exec(&fixed));
        assert_eq!(before.output().to_vec(), after.output().to_vec());
        assert!(after.total_energy_j < before.total_energy_j);
    }

    #[test]
    fn remove_refuses_dangling_consumer() {
        let mut g = Graph::new("t");
        let x = g.add(OpKind::Input, &[], "x");
        let t = g.add(OpKind::Tanh, &[x], "mid");
        g.add(OpKind::Output, &[t], "out");
        let p = Program::new(g);
        let err = apply_rewrite(&p, &[RewriteStep::Remove { node: t }]).unwrap_err();
        assert!(err.to_string().contains("still consumed"), "got: {err}");
    }

    #[test]
    fn fuse_addmm_preserves_semantics() {
        let mut rng = crate::util::Prng::new(7);
        let mut g = Graph::new("lin");
        let x = g.add(OpKind::Input, &[], "x");
        let w = g.add(OpKind::Weight, &[], "w");
        let b = g.add(OpKind::Weight, &[], "b");
        let m = g.add(OpKind::MatMul, &[x, w], "lin.matmul");
        let a = g.add(OpKind::Add, &[m, b], "lin.add_bias");
        g.add(OpKind::Output, &[a], "out");
        let mut p = Program::new(g);
        p.feed(x, Tensor::randn(&mut rng, &[8, 12]));
        p.feed(w, Tensor::randn(&mut rng, &[12, 4]));
        p.feed(b, Tensor::randn(&mut rng, &[4]));
        let fixed = apply_rewrite(&p, &[RewriteStep::FuseAddMm { mm: m, add: a }]).unwrap();
        assert_eq!(fixed.graph.len(), 5);
        let addmm = fixed.graph.nodes.iter().find(|n| n.op == OpKind::AddMm).unwrap();
        assert_eq!(addmm.label, "lin.add_bias");
        let (before, after) = (exec(&p), exec(&fixed));
        let d = before.output().max_abs_diff(after.output());
        assert!(d < 1e-5, "fused output drifted by {d}");
        assert!(after.total_energy_j < before.total_energy_j);
    }

    #[test]
    fn set_attr_lands_on_kept_node() {
        let mut g = Graph::new("t");
        let x = g.add(OpKind::Input, &[], "x");
        let t = g.add(OpKind::Tanh, &[x], "mid");
        g.add(OpKind::Output, &[t], "out");
        let p = Program::new(g);
        let fixed = apply_rewrite(
            &p,
            &[RewriteStep::SetAttr { node: t, key: "k".into(), value: "v".into() }],
        )
        .unwrap();
        assert_eq!(fixed.graph.nodes[1].attrs.get("k").map(String::as_str), Some("v"));
    }
}
