//! The initial lint rule set, grounded in the paper's root-cause
//! taxonomy (§6): redundant operations (dead subgraphs, duplicated
//! subexpressions, layout round-trips, redundant copies, materialised
//! broadcast expansion, redundant synchronisation), API misuse (unfused
//! matmul+add), and algebraic no-ops that cost a kernel launch for
//! identity math. Each rule reports the nodes involved, the joules the
//! executor would bill for them, and — where the fix is mechanical — a
//! rewrite that [`super::rewrite::apply_rewrite`] can perform.

use std::collections::{BTreeMap, BTreeSet};

use crate::dispatch::VarSource;
use crate::graph::{NodeId, OpKind};

use super::{attr_csv, attr_f64, attr_usize, LintContext, LintFinding, LintPass, RewriteStep, Severity};

/// The default rule set, in stable order.
pub fn default_passes() -> Vec<Box<dyn LintPass>> {
    vec![
        Box::new(DeadSubgraph),
        Box::new(CseDuplicate),
        Box::new(AlgebraicNoop),
        Box::new(RedundantCopy),
        Box::new(LayoutRoundtrip),
        Box::new(ConcatSplitRoundtrip),
        Box::new(RepeatBroadcast),
        Box::new(UnfusedMatmulAdd),
        Box::new(RedundantSync),
        Box::new(IdempotentOp),
        Box::new(DeadWeight),
        Box::new(DtypeDowncast),
        Box::new(DispatchAttr),
    ]
}

/// Every rule name `lint --only` accepts: the graph passes plus the
/// rules emitted outside the pass framework (config lints and the
/// static differential audit).
pub fn rule_names() -> Vec<&'static str> {
    let mut names: Vec<&'static str> = default_passes().iter().map(|p| p.name()).collect();
    names.extend([
        "stream-config",
        "detect-config",
        "static-diff",
        "static-diff-unmatched",
        "interaction",
    ]);
    names
}

// ---------------------------------------------------------------------
// dead-subgraph
// ---------------------------------------------------------------------

/// Nodes that reach no `Output`: the executor still runs and bills them
/// (it walks construction order, not liveness).
pub struct DeadSubgraph;

impl LintPass for DeadSubgraph {
    fn name(&self) -> &'static str {
        "dead-subgraph"
    }

    fn run(&self, cx: &LintContext) -> Vec<LintFinding> {
        let g = cx.graph;
        let outputs: Vec<NodeId> =
            g.nodes.iter().filter(|n| n.op == OpKind::Output).map(|n| n.id).collect();
        if outputs.is_empty() {
            return vec![]; // output-less graphs have no liveness notion
        }
        let mut live = vec![false; g.len()];
        for &o in &outputs {
            for (id, reach) in g.reaching(o).into_iter().enumerate() {
                live[id] = live[id] || reach;
            }
        }
        let dead: Vec<NodeId> = (0..g.len()).filter(|&id| !live[id]).collect();
        if dead.is_empty() {
            return vec![];
        }
        let est: f64 = dead.iter().map(|&id| cx.cost_j(id)).sum();
        // representative site: the most expensive dead node
        let top = dead
            .iter()
            .copied()
            .max_by(|&a, &b| cx.cost_j(a).total_cmp(&cx.cost_j(b)).then(b.cmp(&a)))
            .expect("non-empty");
        vec![LintFinding {
            rule: "dead-subgraph",
            severity: Severity::Warn,
            nodes: dead.clone(),
            label: g.nodes[top].label.clone(),
            est_wasted_j: est,
            suggestion: format!(
                "{} node(s) never reach an Output but are still executed and billed; \
                 delete the dead subgraph",
                dead.len()
            ),
            steps: dead.iter().map(|&node| RewriteStep::Remove { node }).collect(),
        }]
    }
}

// ---------------------------------------------------------------------
// cse-duplicate
// ---------------------------------------------------------------------

/// Structurally identical subtrees computed more than once: bucket the
/// subtree hashes and point every duplicate at the first occurrence.
pub struct CseDuplicate;

impl LintPass for CseDuplicate {
    fn name(&self) -> &'static str {
        "cse-duplicate"
    }

    fn run(&self, cx: &LintContext) -> Vec<LintFinding> {
        let g = cx.graph;
        let mut buckets: BTreeMap<u64, Vec<NodeId>> = BTreeMap::new();
        for node in &g.nodes {
            if node.op.is_virtual() || node.inputs.is_empty() {
                continue;
            }
            buckets.entry(cx.hashes[node.id]).or_default().push(node.id);
        }
        let mut out = Vec::new();
        for (_, ids) in buckets {
            if ids.len() < 2 {
                continue;
            }
            let canon = ids[0];
            // hash-collision paranoia: duplicates must agree on op + shape
            let dups: Vec<NodeId> = ids[1..]
                .iter()
                .copied()
                .filter(|&d| {
                    g.nodes[d].op == g.nodes[canon].op && cx.shapes[d] == cx.shapes[canon]
                })
                .collect();
            if dups.is_empty() {
                continue;
            }
            // bypassing a duplicate also kills its exclusive input cone:
            // any producer whose every consumer is being removed is
            // billed for nothing once the duplicate reads the canonical
            // output. Grow the removed set to that fixpoint (sources and
            // the canonical node itself are always kept).
            let mut removed: BTreeSet<NodeId> = dups.iter().copied().collect();
            let mut changed = true;
            while changed {
                changed = false;
                for &id in cx.topo.iter().rev() {
                    if removed.contains(&id)
                        || id == canon
                        || matches!(
                            g.nodes[id].op,
                            OpKind::Input | OpKind::Weight | OpKind::Output
                        )
                        || cx.consumers[id].is_empty()
                    {
                        continue;
                    }
                    if cx.consumers[id].iter().all(|c| removed.contains(c)) {
                        removed.insert(id);
                        changed = true;
                    }
                }
            }
            // cone in reverse topo order, so Removes delete consumers
            // before their producers
            let cone: Vec<NodeId> = cx
                .topo
                .iter()
                .rev()
                .copied()
                .filter(|id| removed.contains(id) && !dups.contains(id))
                .collect();
            let est: f64 = removed.iter().map(|&d| cx.cost_j(d)).sum();
            let mut nodes = vec![canon];
            nodes.extend(removed.iter().copied());
            nodes.sort_unstable();
            let mut steps: Vec<RewriteStep> = dups
                .iter()
                .map(|&d| RewriteStep::Bypass { node: d, replacement: canon })
                .collect();
            steps.extend(cone.iter().map(|&node| RewriteStep::Remove { node }));
            out.push(LintFinding {
                rule: "cse-duplicate",
                severity: Severity::Warn,
                nodes,
                label: g.nodes[canon].label.clone(),
                est_wasted_j: est,
                suggestion: format!(
                    "{} duplicate(s) of `{}` recompute an identical subtree; reuse its \
                     output{}",
                    dups.len(),
                    g.nodes[canon].label,
                    if cone.is_empty() {
                        String::new()
                    } else {
                        format!(
                            " (and drop {} upstream node(s) only the duplicate consumed)",
                            cone.len()
                        )
                    }
                ),
                steps,
            });
        }
        out
    }
}

// ---------------------------------------------------------------------
// algebraic-noop
// ---------------------------------------------------------------------

/// Identity math that still launches a kernel: `Scale(1)`, `Pow(1)`,
/// `Contiguous` straight after `Contiguous`, back-to-back `Copy`.
pub struct AlgebraicNoop;

impl LintPass for AlgebraicNoop {
    fn name(&self) -> &'static str {
        "algebraic-noop"
    }

    fn run(&self, cx: &LintContext) -> Vec<LintFinding> {
        let g = cx.graph;
        let mut out = Vec::new();
        for node in &g.nodes {
            let input_op = node.inputs.first().map(|&i| g.nodes[i].op);
            let reason = match node.op {
                OpKind::Scale if attr_f64(&node.attrs, "s", 1.0) == 1.0 => "scale by 1.0",
                OpKind::Pow if attr_f64(&node.attrs, "p", 2.0) == 1.0 => "pow with exponent 1.0",
                OpKind::Contiguous if input_op == Some(OpKind::Contiguous) => {
                    "contiguous of an already-contiguous tensor"
                }
                OpKind::Copy if input_op == Some(OpKind::Copy) => "copy of a fresh copy",
                _ => continue,
            };
            out.push(LintFinding {
                rule: "algebraic-noop",
                severity: Severity::Warn,
                nodes: vec![node.id],
                label: node.label.clone(),
                est_wasted_j: cx.cost_j(node.id),
                suggestion: format!("`{}` is a no-op ({reason}); drop it", node.label),
                steps: vec![RewriteStep::Bypass { node: node.id, replacement: node.inputs[0] }],
            });
        }
        out
    }
}

// ---------------------------------------------------------------------
// redundant-copy
// ---------------------------------------------------------------------

/// `Copy` of a source tensor (`Input`/`Weight`): the buffer is already
/// resident — the copy is pure HBM traffic (case c2's kv-cache copy).
pub struct RedundantCopy;

impl LintPass for RedundantCopy {
    fn name(&self) -> &'static str {
        "redundant-copy"
    }

    fn run(&self, cx: &LintContext) -> Vec<LintFinding> {
        let g = cx.graph;
        let mut out = Vec::new();
        for node in &g.nodes {
            if node.op != OpKind::Copy {
                continue;
            }
            let src = match node.inputs.first() {
                Some(&i) => i,
                None => continue,
            };
            if !matches!(g.nodes[src].op, OpKind::Input | OpKind::Weight) {
                continue;
            }
            out.push(LintFinding {
                rule: "redundant-copy",
                severity: Severity::Warn,
                nodes: vec![node.id],
                label: node.label.clone(),
                est_wasted_j: cx.cost_j(node.id),
                suggestion: format!(
                    "`{}` copies the already-resident source `{}`; read it in place \
                     (e.g. pass an aligned layout so no staging copy is needed)",
                    node.label, g.nodes[src].label
                ),
                steps: vec![RewriteStep::Bypass { node: node.id, replacement: src }],
            });
        }
        out
    }
}

// ---------------------------------------------------------------------
// layout-roundtrip
// ---------------------------------------------------------------------

/// `Permute → Contiguous → Permute → Contiguous` where the two permutes
/// compose to the identity: two materialised copies for a tensor that
/// ends up exactly where it started (case c5's default-format round
/// trip).
pub struct LayoutRoundtrip;

impl LintPass for LayoutRoundtrip {
    fn name(&self) -> &'static str {
        "layout-roundtrip"
    }

    fn run(&self, cx: &LintContext) -> Vec<LintFinding> {
        let g = cx.graph;
        let mut out = Vec::new();
        for node in &g.nodes {
            // anchor at the trailing Contiguous of the round trip
            let c2 = node;
            if c2.op != OpKind::Contiguous {
                continue;
            }
            let p2 = match c2.inputs.first().map(|&i| &g.nodes[i]) {
                Some(n) if n.op == OpKind::Permute => n,
                _ => continue,
            };
            let c1 = match p2.inputs.first().map(|&i| &g.nodes[i]) {
                Some(n) if n.op == OpKind::Contiguous => n,
                _ => continue,
            };
            let p1 = match c1.inputs.first().map(|&i| &g.nodes[i]) {
                Some(n) if n.op == OpKind::Permute => n,
                _ => continue,
            };
            // the interior of the chain must have no other consumers
            if cx.consumers[p2.id] != [c2.id]
                || cx.consumers[c1.id] != [p2.id]
                || cx.consumers[p1.id] != [c1.id]
            {
                continue;
            }
            let (perm1, perm2) = match (attr_csv(&p1.attrs, "perm"), attr_csv(&p2.attrs, "perm")) {
                (Some(a), Some(b)) if a.len() == b.len() => (a, b),
                _ => continue,
            };
            let identity = perm2.iter().enumerate().all(|(i, &p)| perm1.get(p) == Some(&i));
            if !identity {
                continue;
            }
            let src = match p1.inputs.first() {
                Some(&i) => i,
                None => continue,
            };
            let est = cx.cost_j(c1.id) + cx.cost_j(c2.id);
            out.push(LintFinding {
                rule: "layout-roundtrip",
                severity: Severity::Warn,
                nodes: vec![p1.id, c1.id, p2.id, c2.id],
                label: c2.label.clone(),
                est_wasted_j: est,
                suggestion: format!(
                    "`{}` permutes, materialises, permutes back, and materialises again — \
                     an identity round trip costing two full copies; keep `{}`'s layout",
                    c2.label, g.nodes[src].label
                ),
                steps: vec![
                    RewriteStep::Bypass { node: c2.id, replacement: src },
                    RewriteStep::Remove { node: p2.id },
                    RewriteStep::Remove { node: c1.id },
                    RewriteStep::Remove { node: p1.id },
                ],
            });
        }
        out
    }
}

// ---------------------------------------------------------------------
// concat-split-roundtrip
// ---------------------------------------------------------------------

/// `Concat` whose only consumers split it straight back into the
/// original parts (case c7's skip-connection concat/chunk round trip).
pub struct ConcatSplitRoundtrip;

impl LintPass for ConcatSplitRoundtrip {
    fn name(&self) -> &'static str {
        "concat-split-roundtrip"
    }

    fn run(&self, cx: &LintContext) -> Vec<LintFinding> {
        let g = cx.graph;
        let mut out = Vec::new();
        for node in &g.nodes {
            if node.op != OpKind::Concat || node.inputs.is_empty() {
                continue;
            }
            let dim = attr_usize(&node.attrs, "dim", 0);
            let splits = &cx.consumers[node.id];
            if splits.is_empty() {
                continue;
            }
            // every consumer must be an even SplitChunk along the same
            // dim with as many chunks as the concat has inputs
            let k = node.inputs.len();
            if !splits.iter().all(|&s| {
                let sn = &g.nodes[s];
                sn.op == OpKind::SplitChunk
                    && attr_usize(&sn.attrs, "dim", 0) == dim
                    && attr_usize(&sn.attrs, "chunks", 1) == k
                    && attr_usize(&sn.attrs, "index", 0) < k
            }) {
                continue;
            }
            // chunks are equal-sized only if every part has the same
            // extent along `dim`
            let part = match cx.shapes[node.inputs[0]].as_ref().and_then(|s| s.get(dim)) {
                Some(&d) => d,
                None => continue,
            };
            if !node.inputs.iter().all(|&i| {
                cx.shapes[i].as_ref().and_then(|s| s.get(dim)) == Some(&part)
            }) {
                continue;
            }
            let est =
                cx.cost_j(node.id) + splits.iter().map(|&s| cx.cost_j(s)).sum::<f64>();
            let mut nodes = vec![node.id];
            nodes.extend(splits.iter().copied());
            nodes.sort_unstable();
            let mut steps: Vec<RewriteStep> = splits
                .iter()
                .map(|&s| {
                    let idx = attr_usize(&g.nodes[s].attrs, "index", 0);
                    RewriteStep::Bypass { node: s, replacement: node.inputs[idx] }
                })
                .collect();
            steps.push(RewriteStep::Remove { node: node.id });
            out.push(LintFinding {
                rule: "concat-split-roundtrip",
                severity: Severity::Warn,
                nodes,
                label: node.label.clone(),
                est_wasted_j: est,
                suggestion: format!(
                    "`{}` concatenates {} tensors only to split them straight back; use \
                     the original tensors directly",
                    node.label, k
                ),
                steps,
            });
        }
        out
    }
}

// ---------------------------------------------------------------------
// repeat-broadcast
// ---------------------------------------------------------------------

/// Materialised `RepeatInterleave` feeding an op that can broadcast the
/// expansion itself — the paper's flagship redundant-operation case
/// (c4's GQA head expansion): the attention kernel takes `gqa_reps` and
/// expands in-kernel for free.
pub struct RepeatBroadcast;

impl LintPass for RepeatBroadcast {
    fn name(&self) -> &'static str {
        "repeat-broadcast"
    }

    fn run(&self, cx: &LintContext) -> Vec<LintFinding> {
        let g = cx.graph;
        let mut out = Vec::new();
        // (a) rewritable: repeats whose sole consumer is an Attention
        // that does not already expand in-kernel
        for attn in &g.nodes {
            if attn.op != OpKind::Attention || attr_usize(&attn.attrs, "gqa_reps", 1) > 1 {
                continue;
            }
            let reps_nodes: Vec<NodeId> = attn
                .inputs
                .iter()
                .copied()
                .filter(|&i| {
                    g.nodes[i].op == OpKind::RepeatInterleave
                        && attr_usize(&g.nodes[i].attrs, "reps", 1) > 1
                        && cx.consumers[i] == [attn.id]
                })
                .collect();
            if reps_nodes.is_empty() {
                continue;
            }
            let reps = attr_usize(&g.nodes[reps_nodes[0]].attrs, "reps", 1);
            if !reps_nodes
                .iter()
                .all(|&r| attr_usize(&g.nodes[r].attrs, "reps", 1) == reps)
            {
                continue; // mixed factors cannot fold into one gqa_reps
            }
            let est: f64 = reps_nodes.iter().map(|&r| cx.cost_j(r)).sum();
            let mut nodes = reps_nodes.clone();
            nodes.push(attn.id);
            nodes.sort_unstable();
            let mut steps: Vec<RewriteStep> = reps_nodes
                .iter()
                .map(|&r| RewriteStep::Bypass { node: r, replacement: g.nodes[r].inputs[0] })
                .collect();
            steps.push(RewriteStep::SetAttr {
                node: attn.id,
                key: "gqa_reps".into(),
                value: reps.to_string(),
            });
            out.push(LintFinding {
                rule: "repeat-broadcast",
                severity: Severity::Warn,
                nodes,
                label: g.nodes[reps_nodes[0]].label.clone(),
                est_wasted_j: est,
                suggestion: format!(
                    "`{}` materialises a {reps}x head expansion that `{}` can broadcast \
                     in-kernel; pass gqa_reps={reps} instead",
                    g.nodes[reps_nodes[0]].label, attn.label
                ),
                steps,
            });
        }
        // (b) advisory: repeats feeding only broadcast-capable
        // elementwise ops (no mechanical rewrite: the operand would need
        // a singleton dim for broadcasting to kick in)
        for node in &g.nodes {
            if node.op != OpKind::RepeatInterleave
                || attr_usize(&node.attrs, "reps", 1) <= 1
                || cx.consumers[node.id].is_empty()
            {
                continue;
            }
            let all_elementwise = cx.consumers[node.id].iter().all(|&c| {
                matches!(g.nodes[c].op, OpKind::Add | OpKind::Sub | OpKind::Mul | OpKind::Div)
            });
            if !all_elementwise {
                continue;
            }
            out.push(LintFinding {
                rule: "repeat-broadcast",
                severity: Severity::Info,
                nodes: vec![node.id],
                label: node.label.clone(),
                est_wasted_j: cx.cost_j(node.id),
                suggestion: format!(
                    "`{}` materialises a repeat that only feeds elementwise ops; a \
                     broadcastable view (singleton dim) would avoid the copy",
                    node.label
                ),
                steps: vec![],
            });
        }
        out
    }
}

// ---------------------------------------------------------------------
// unfused-matmul-add
// ---------------------------------------------------------------------

/// `MatMul` whose only consumer adds a bias: a fused `AddMm` saves the
/// intermediate's HBM round trip and a launch. Reported only when the
/// target's own dispatcher prices the fused kernel cheaper (a system
/// with a power-hungry addmm epilogue, case c10, would not benefit).
pub struct UnfusedMatmulAdd;

impl LintPass for UnfusedMatmulAdd {
    fn name(&self) -> &'static str {
        "unfused-matmul-add"
    }

    fn run(&self, cx: &LintContext) -> Vec<LintFinding> {
        let g = cx.graph;
        let mut out = Vec::new();
        for mm in &g.nodes {
            if mm.op != OpKind::MatMul || cx.consumers[mm.id].len() != 1 {
                continue;
            }
            let add = &g.nodes[cx.consumers[mm.id][0]];
            if add.op != OpKind::Add || add.inputs.len() != 2 {
                continue;
            }
            let bias = match add.inputs.iter().copied().find(|&i| i != mm.id) {
                Some(b) => b,
                None => continue, // add(m, m) is not a bias pattern
            };
            let (x, w) = match (mm.inputs.first(), mm.inputs.get(1)) {
                (Some(&x), Some(&w)) => (x, w),
                _ => continue,
            };
            let shapes = |ids: &[NodeId]| -> Option<Vec<Vec<usize>>> {
                ids.iter().map(|&i| cx.shapes[i].clone()).collect()
            };
            let (in_shapes, out_shape) = match (shapes(&[bias, x, w]), cx.shapes[add.id].clone()) {
                (Some(i), Some(o)) => (i, o),
                _ => continue,
            };
            let fused = cx.op_cost(OpKind::AddMm, &Default::default(), &in_shapes, &out_shape);
            let est = cx.cost_j(mm.id) + cx.cost_j(add.id) - fused.energy_j;
            if est <= 0.0 {
                continue; // fusion would not pay on this dispatcher
            }
            out.push(LintFinding {
                rule: "unfused-matmul-add",
                severity: Severity::Info,
                nodes: vec![mm.id, add.id],
                label: mm.label.clone(),
                est_wasted_j: est,
                suggestion: format!(
                    "`{}` + `{}` round-trip the GEMM output through HBM; a fused addmm \
                     kernel saves the intermediate",
                    mm.label, add.label
                ),
                steps: vec![RewriteStep::FuseAddMm { mm: mm.id, add: add.id }],
            });
        }
        out
    }
}

// ---------------------------------------------------------------------
// redundant-sync
// ---------------------------------------------------------------------

/// A `Barrier` that dominates no `AllReduce`: nothing downstream needs
/// the rendezvous, so the GPU spins near base power for nothing (case
/// c9's `dist.Join` busy-wait after the collective already finished).
pub struct RedundantSync;

impl LintPass for RedundantSync {
    fn name(&self) -> &'static str {
        "redundant-sync"
    }

    fn run(&self, cx: &LintContext) -> Vec<LintFinding> {
        let g = cx.graph;
        let mut out = Vec::new();
        for node in &g.nodes {
            if node.op != OpKind::Barrier {
                continue;
            }
            let guards_collective = g.nodes.iter().any(|n| {
                n.op == OpKind::AllReduce && n.id != node.id && cx.dom.dom.dominates(node.id, n.id)
            });
            if guards_collective {
                continue;
            }
            let steps = match node.inputs.first() {
                Some(&i) => vec![RewriteStep::Bypass { node: node.id, replacement: i }],
                None => vec![RewriteStep::Remove { node: node.id }],
            };
            out.push(LintFinding {
                rule: "redundant-sync",
                severity: Severity::Warn,
                nodes: vec![node.id],
                label: node.label.clone(),
                est_wasted_j: cx.cost_j(node.id),
                suggestion: format!(
                    "`{}` gates no collective (it dominates no all_reduce); the busy-wait \
                     burns power for nothing — drop the barrier or use an event wait",
                    node.label
                ),
                steps,
            });
        }
        out
    }
}

// ---------------------------------------------------------------------
// idempotent-op
// ---------------------------------------------------------------------

/// An idempotent op applied straight to its own output: `Relu∘Relu`
/// and `Sort∘Sort` are exact identities, and `Softmax∘Softmax` — while
/// not an identity — is the classic double-normalisation bug (a
/// pre-softmaxed input handed to a path that softmaxes again). Either
/// way the second kernel is wasted work.
pub struct IdempotentOp;

impl LintPass for IdempotentOp {
    fn name(&self) -> &'static str {
        "idempotent-op"
    }

    fn run(&self, cx: &LintContext) -> Vec<LintFinding> {
        let g = cx.graph;
        let mut out = Vec::new();
        for node in &g.nodes {
            if !matches!(node.op, OpKind::Softmax | OpKind::Relu | OpKind::Sort) {
                continue;
            }
            let inner = match node.inputs.first() {
                Some(&i) => &g.nodes[i],
                None => continue,
            };
            if inner.op != node.op || inner.attrs != node.attrs {
                continue;
            }
            out.push(LintFinding {
                rule: "idempotent-op",
                severity: Severity::Warn,
                nodes: vec![inner.id, node.id],
                label: node.label.clone(),
                est_wasted_j: cx.cost_j(node.id),
                suggestion: format!(
                    "`{}` reapplies {} to `{}`'s output; the second application is \
                     wasted work (and for softmax almost always a normalisation bug)",
                    node.label,
                    node.op.name(),
                    inner.label
                ),
                steps: vec![RewriteStep::Bypass { node: node.id, replacement: inner.id }],
            });
        }
        out
    }
}

// ---------------------------------------------------------------------
// dead-weight
// ---------------------------------------------------------------------

/// A `Weight` feed that never reaches any `Output`: the parameter is
/// declared, fed, and kept resident in HBM without contributing to the
/// result. Costs no kernel energy in the static model (sources are
/// virtual), so the finding is about residency and intent — a per-feed
/// sharper companion to the blanket `dead-subgraph` rule.
pub struct DeadWeight;

impl LintPass for DeadWeight {
    fn name(&self) -> &'static str {
        "dead-weight"
    }

    fn run(&self, cx: &LintContext) -> Vec<LintFinding> {
        let g = cx.graph;
        let outputs: Vec<NodeId> =
            g.nodes.iter().filter(|n| n.op == OpKind::Output).map(|n| n.id).collect();
        if outputs.is_empty() {
            return vec![];
        }
        let mut live = vec![false; g.len()];
        for &o in &outputs {
            for (id, reach) in g.reaching(o).into_iter().enumerate() {
                live[id] = live[id] || reach;
            }
        }
        let mut out = Vec::new();
        for node in &g.nodes {
            if node.op != OpKind::Weight || live[node.id] {
                continue;
            }
            let elems = cx.shapes[node.id].as_ref().map(|s| s.iter().product::<usize>());
            let steps = if cx.consumers[node.id].is_empty() {
                vec![RewriteStep::Remove { node: node.id }]
            } else {
                vec![] // its consumers are dead too; dead-subgraph owns that cone
            };
            out.push(LintFinding {
                rule: "dead-weight",
                severity: Severity::Warn,
                nodes: vec![node.id],
                label: node.label.clone(),
                est_wasted_j: 0.0,
                suggestion: format!(
                    "weight `{}`{} never reaches an Output; it is declared, fed, and \
                     kept resident for nothing — drop the feed",
                    node.label,
                    elems.map_or(String::new(), |n| format!(" ({n} elements)"))
                ),
                steps,
            });
        }
        out
    }
}

// ---------------------------------------------------------------------
// dtype-downcast
// ---------------------------------------------------------------------

/// One flagged dispatch site: what it runs now and what a single flag
/// flip would select instead.
struct DowncastSite {
    node: NodeId,
    saved_j: f64,
    kernel_now: String,
    kernel_then: String,
    current_val: String,
    source: String,
}

/// Symbolic dispatch coverage (misconfiguration class): enumerate each
/// routine's finite config-flag space and flag nodes whose selected
/// kernel is strictly energy-dominated by a reachable alternative that
/// one `ConfigFlag`-sourced variable away — the paper's fp32-SGEMM-on-
/// a-TensorCore-capable-routine case (`allow_tf32` unset). Only flags
/// assignments that cost strictly less energy at no time cost, and only
/// variables a developer can actually set (config flags — not API
/// arguments or input properties, which the call site determines).
pub struct DtypeDowncast;

impl LintPass for DtypeDowncast {
    fn name(&self) -> &'static str {
        "dtype-downcast"
    }

    fn run(&self, cx: &LintContext) -> Vec<LintFinding> {
        let g = cx.graph;
        // one finding per (flag, cheaper value), covering every node it fixes
        let mut groups: BTreeMap<(String, String), Vec<DowncastSite>> = BTreeMap::new();
        for node in &g.nodes {
            if node.op.is_virtual() {
                continue;
            }
            let cur = &cx.cost[node.id];
            let (cur_e, cur_t) = (cur.energy_j, cur.time_us);
            if cur_e <= 0.0 {
                continue;
            }
            let out_shape = match &cx.shapes[node.id] {
                Some(s) => s.clone(),
                None => continue,
            };
            let in_shapes: Option<Vec<Vec<usize>>> =
                node.inputs.iter().map(|&i| cx.shapes[i].clone()).collect();
            let in_shapes = match in_shapes {
                Some(s) => s,
                None => continue,
            };
            let key = node
                .attrs
                .get("dispatch")
                .cloned()
                .unwrap_or_else(|| node.op.name().to_string());
            let routine = cx.dispatcher.routine_for(node.op, &key);
            if routine.provenance.is_empty() {
                continue; // direct routine: no config space to explore
            }
            let merged = cx.env.merged(&node.attrs);
            let kernel_now = routine.run(&merged).choice.kernel;
            let mut best: Option<(String, String, f64, String)> = None;
            for outcome in routine.enumerate_outcomes() {
                // a useful fix differs from the live env in exactly one
                // variable, and that variable must be a config flag
                let diffs: Vec<(&String, &String)> = outcome
                    .assignment
                    .iter()
                    .filter(|(k, v)| merged.get(k) != v.as_str())
                    .collect();
                if diffs.len() != 1 {
                    continue;
                }
                let (var, val) = diffs[0];
                if !matches!(routine.source_of(var), Some(VarSource::ConfigFlag(_))) {
                    continue;
                }
                let mut attrs = node.attrs.clone();
                attrs.insert(var.clone(), val.clone());
                let cand = cx.op_cost(node.op, &attrs, &in_shapes, &out_shape);
                if cand.energy_j < cur_e && cand.time_us <= cur_t {
                    let saved = cur_e - cand.energy_j;
                    if best.as_ref().map_or(true, |b| saved > b.2) {
                        best = Some((
                            var.clone(),
                            val.clone(),
                            saved,
                            outcome.choice.kernel.clone(),
                        ));
                    }
                }
            }
            if let Some((var, val, saved_j, kernel_then)) = best {
                let source = routine
                    .source_of(&var)
                    .map(|s| s.describe())
                    .unwrap_or_else(|| format!("variable `{var}`"));
                groups.entry((var, val)).or_default().push(DowncastSite {
                    node: node.id,
                    saved_j,
                    kernel_now: kernel_now.clone(),
                    kernel_then,
                    current_val: merged.get(&var).to_string(),
                    source,
                });
            }
        }
        let mut out = Vec::new();
        for ((var, val), sites) in groups {
            let est: f64 = sites.iter().map(|s| s.saved_j).sum();
            let top = sites
                .iter()
                .max_by(|a, b| a.saved_j.total_cmp(&b.saved_j).then(b.node.cmp(&a.node)))
                .expect("non-empty");
            let mut nodes: Vec<NodeId> = sites.iter().map(|s| s.node).collect();
            nodes.sort_unstable();
            let steps = nodes
                .iter()
                .map(|&node| RewriteStep::SetAttr {
                    node,
                    key: var.clone(),
                    value: val.clone(),
                })
                .collect();
            let cur_disp = if top.current_val.is_empty() {
                "unset".to_string()
            } else {
                format!("`{}`", top.current_val)
            };
            out.push(LintFinding {
                rule: "dtype-downcast",
                severity: Severity::Warn,
                nodes,
                label: g.nodes[top.node].label.clone(),
                est_wasted_j: est,
                suggestion: format!(
                    "{} kernel(s) run {} because {} is {}; setting `{}={}` selects {} — \
                     strictly less energy at no time cost",
                    sites.len(),
                    top.kernel_now,
                    top.source,
                    cur_disp,
                    var,
                    val,
                    top.kernel_then
                ),
                steps,
            });
        }
        out
    }
}

// ---------------------------------------------------------------------
// dispatch-attr
// ---------------------------------------------------------------------

/// Fused kernels a dispatcher registers that no graph node ever
/// requests (API-misuse class): the system ships a cheaper
/// implementation but the model never opts in via its `dispatch`
/// attribute. Only keys plausibly relevant to the graph are reported —
/// some present op's name must appear in the key or the routine's API —
/// so a framework dispatcher registering kernels for absent op families
/// stays quiet.
pub struct DispatchAttr;

impl LintPass for DispatchAttr {
    fn name(&self) -> &'static str {
        "dispatch-attr"
    }

    fn run(&self, cx: &LintContext) -> Vec<LintFinding> {
        let g = cx.graph;
        let mut requested: BTreeSet<String> = BTreeSet::new();
        let mut present: BTreeSet<&'static str> = BTreeSet::new();
        for node in &g.nodes {
            if node.op.is_virtual() {
                continue;
            }
            requested.insert(
                node.attrs
                    .get("dispatch")
                    .cloned()
                    .unwrap_or_else(|| node.op.name().to_string()),
            );
            present.insert(node.op.name());
        }
        let mut out = Vec::new();
        for (key, routine) in &cx.dispatcher.routines {
            if requested.contains(key) {
                continue;
            }
            let relevant =
                present.iter().any(|op| key.contains(op) || routine.api.contains(op));
            if !relevant {
                continue;
            }
            out.push(LintFinding {
                rule: "dispatch-attr",
                severity: Severity::Info,
                nodes: vec![],
                label: key.clone(),
                est_wasted_j: 0.0,
                suggestion: format!(
                    "dispatcher registers `{key}` (api `{}`) but no node requests it; \
                     eligible nodes could opt in via a `dispatch=\"{key}\"` attribute",
                    routine.api
                ),
                steps: vec![],
            });
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dispatch::Env;
    use crate::energy::DeviceSpec;
    use crate::exec::{Dispatcher, Program};
    use crate::graph::Graph;
    use crate::tensor::Tensor;

    struct Harness {
        prog: Program,
        dispatcher: Dispatcher,
        env: Env,
        device: DeviceSpec,
    }

    impl Harness {
        fn new(prog: Program) -> Harness {
            Harness {
                prog,
                dispatcher: Dispatcher::new(),
                env: Env::new(),
                device: DeviceSpec::h200_sim(),
            }
        }

        fn lint(&self) -> Vec<LintFinding> {
            let cx =
                LintContext::new(&self.prog, &self.dispatcher, &self.env, &self.device).unwrap();
            super::super::lint_graph(&cx)
        }
    }

    fn feed_x(p: &mut Program, shape: &[usize]) {
        p.feed(0, Tensor::zeros(shape));
    }

    #[test]
    fn dead_subgraph_is_found_and_costed() {
        let mut g = Graph::new("dead");
        let x = g.add(OpKind::Input, &[], "x");
        let live = g.add(OpKind::Gelu, &[x], "live");
        let dead = g.add(OpKind::Tanh, &[x], "dead.branch");
        let dead2 = g.add(OpKind::Gelu, &[dead], "dead.tip");
        g.add(OpKind::Output, &[live], "out");
        let mut p = Program::new(g);
        feed_x(&mut p, &[64, 64]);
        let h = Harness::new(p);
        let f = h.lint();
        let dead_f: Vec<_> = f.iter().filter(|f| f.rule == "dead-subgraph").collect();
        assert_eq!(dead_f.len(), 1);
        assert_eq!(dead_f[0].nodes, vec![dead, dead2]);
        assert!(dead_f[0].est_wasted_j > 0.0);
    }

    #[test]
    fn cse_duplicates_bucket_together() {
        let mut g = Graph::new("cse");
        let x = g.add(OpKind::Input, &[], "x");
        let a = g.add(OpKind::Gelu, &[x], "act.a");
        let b = g.add(OpKind::Gelu, &[x], "act.b"); // duplicate of a
        let s = g.add(OpKind::Add, &[a, b], "sum");
        g.add(OpKind::Output, &[s], "out");
        let mut p = Program::new(g);
        feed_x(&mut p, &[32, 32]);
        let f = Harness::new(p).lint();
        let cse: Vec<_> = f.iter().filter(|f| f.rule == "cse-duplicate").collect();
        assert_eq!(cse.len(), 1);
        assert_eq!(cse[0].nodes, vec![a, b]);
        assert_eq!(cse[0].steps, vec![RewriteStep::Bypass { node: b, replacement: a }]);
    }

    #[test]
    fn algebraic_noops_scale_pow_contiguous() {
        let mut g = Graph::new("noop");
        let x = g.add(OpKind::Input, &[], "x");
        let s1 = g.add_attr1(OpKind::Scale, &[x], "scale.one", "s", "1.0");
        let p1 = g.add_attr1(OpKind::Pow, &[s1], "pow.one", "p", "1");
        let c1 = g.add(OpKind::Contiguous, &[p1], "contig.a");
        let c2 = g.add(OpKind::Contiguous, &[c1], "contig.b");
        g.add(OpKind::Output, &[c2], "out");
        let mut p = Program::new(g);
        feed_x(&mut p, &[16, 16]);
        let f = Harness::new(p).lint();
        let noops: Vec<&str> = f
            .iter()
            .filter(|f| f.rule == "algebraic-noop")
            .map(|f| f.label.as_str())
            .collect();
        assert!(noops.contains(&"scale.one"));
        assert!(noops.contains(&"pow.one"));
        assert!(noops.contains(&"contig.b"));
        assert!(!noops.contains(&"contig.a"), "first contiguous is not a no-op");
        // a real scale must not be flagged
        assert!(!f.iter().any(|f| f.label == "scale.half"));
    }

    #[test]
    fn scale_with_real_factor_not_flagged() {
        let mut g = Graph::new("ok");
        let x = g.add(OpKind::Input, &[], "x");
        let s = g.add_attr1(OpKind::Scale, &[x], "scale.half", "s", "0.5");
        g.add(OpKind::Output, &[s], "out");
        let mut p = Program::new(g);
        feed_x(&mut p, &[8]);
        let f = Harness::new(p).lint();
        assert!(!f.iter().any(|f| f.rule == "algebraic-noop"));
    }

    #[test]
    fn layout_roundtrip_identity_perms_only() {
        let build = |perm2: &str| {
            let mut g = Graph::new("rt");
            let x = g.add(OpKind::Input, &[], "x");
            let p1 = g.add_attr1(OpKind::Permute, &[x], "to_hnd", "perm", "0,2,1,3");
            let c1 = g.add(OpKind::Contiguous, &[p1], "fmt_copy");
            let p2 = g.add_attr1(OpKind::Permute, &[c1], "back", "perm", perm2);
            let c2 = g.add(OpKind::Contiguous, &[p2], "fmt_copy2");
            g.add(OpKind::Output, &[c2], "out");
            let mut p = Program::new(g);
            feed_x(&mut p, &[2, 4, 8, 16]);
            Harness::new(p).lint()
        };
        let f = build("0,2,1,3"); // involution: identity round trip
        let rt: Vec<_> = f.iter().filter(|f| f.rule == "layout-roundtrip").collect();
        assert_eq!(rt.len(), 1);
        assert_eq!(rt[0].nodes, vec![1, 2, 3, 4]);
        // a non-inverse second permute is NOT a round trip
        let f = build("0,3,1,2");
        assert!(!f.iter().any(|f| f.rule == "layout-roundtrip"));
    }

    #[test]
    fn barrier_guarding_collective_not_flagged() {
        let mut g = Graph::new("sync");
        let x = g.add(OpKind::Input, &[], "grads");
        let b = g.add(OpKind::Barrier, &[x], "pre.barrier");
        let ar = g.add(OpKind::AllReduce, &[b], "ddp.all_reduce");
        g.add(OpKind::Output, &[ar], "out");
        let mut p = Program::new(g);
        feed_x(&mut p, &[1024]);
        let f = Harness::new(p).lint();
        assert!(!f.iter().any(|f| f.rule == "redundant-sync"));
    }

    #[test]
    fn barrier_after_collective_is_flagged() {
        let mut g = Graph::new("sync2");
        let x = g.add(OpKind::Input, &[], "grads");
        let ar = g.add(OpKind::AllReduce, &[x], "ddp.all_reduce");
        let b = g.add(OpKind::Barrier, &[ar], "dist.Join.barrier");
        g.add(OpKind::Output, &[b], "out");
        let mut p = Program::new(g);
        feed_x(&mut p, &[1024]);
        let f = Harness::new(p).lint();
        let sync: Vec<_> = f.iter().filter(|f| f.rule == "redundant-sync").collect();
        assert_eq!(sync.len(), 1);
        assert_eq!(sync[0].nodes, vec![b]);
        assert!(sync[0].est_wasted_j > 0.0, "barrier busy-wait must carry a cost");
    }

    #[test]
    fn unfused_matmul_add_suggests_fusion() {
        let mut g = Graph::new("lin");
        let x = g.add(OpKind::Input, &[], "x");
        let w = g.add(OpKind::Weight, &[], "w");
        let bias = g.add(OpKind::Weight, &[], "b");
        let m = g.add(OpKind::MatMul, &[x, w], "lin.matmul");
        let a = g.add(OpKind::Add, &[m, bias], "lin.add_bias");
        g.add(OpKind::Output, &[a], "out");
        let mut p = Program::new(g);
        p.feed(0, Tensor::zeros(&[64, 128]));
        p.feed(1, Tensor::zeros(&[128, 32]));
        p.feed(2, Tensor::zeros(&[32]));
        let f = Harness::new(p).lint();
        let fused: Vec<_> = f.iter().filter(|f| f.rule == "unfused-matmul-add").collect();
        assert_eq!(fused.len(), 1);
        assert_eq!(fused[0].nodes, vec![m, a]);
        assert_eq!(fused[0].steps, vec![RewriteStep::FuseAddMm { mm: m, add: a }]);
        assert!(fused[0].est_wasted_j > 0.0);
    }

    #[test]
    fn repeat_into_attention_rewrites_to_gqa_attr() {
        let mut g = Graph::new("gqa");
        let q = g.add(OpKind::Input, &[], "q");
        let k = g.add(OpKind::Input, &[], "k");
        let v = g.add(OpKind::Input, &[], "v");
        let mut at = crate::graph::Attrs::new();
        at.insert("dim".into(), "2".into());
        at.insert("reps".into(), "2".into());
        let kr = g.add_attrs(OpKind::RepeatInterleave, &[k], "attn.k_repeat_interleave", at.clone());
        let vr = g.add_attrs(OpKind::RepeatInterleave, &[v], "attn.v_repeat_interleave", at);
        let mut aat = crate::graph::Attrs::new();
        aat.insert("layout".into(), "nhd".into());
        let attn = g.add_attrs(OpKind::Attention, &[q, kr, vr], "attn.flash", aat);
        g.add(OpKind::Output, &[attn], "out");
        let mut p = Program::new(g);
        p.feed(0, Tensor::zeros(&[1, 8, 4, 16]));
        p.feed(1, Tensor::zeros(&[1, 8, 2, 16]));
        p.feed(2, Tensor::zeros(&[1, 8, 2, 16]));
        let f = Harness::new(p).lint();
        let rb: Vec<_> = f.iter().filter(|f| f.rule == "repeat-broadcast").collect();
        assert_eq!(rb.len(), 1);
        assert_eq!(rb[0].nodes, vec![kr, vr, attn]);
        assert!(rb[0]
            .steps
            .contains(&RewriteStep::SetAttr { node: attn, key: "gqa_reps".into(), value: "2".into() }));
    }

    #[test]
    fn cse_cone_includes_exclusive_upstream() {
        // x → trunk → t1 → r1 ─┐
        //          ↘ t2 → r2 ──┴→ combine
        let mut g = Graph::new("cone");
        let x = g.add(OpKind::Input, &[], "x");
        let m = g.add(OpKind::Gelu, &[x], "trunk");
        let t1 = g.add(OpKind::Tanh, &[m], "branch1.tanh");
        let r1 = g.add(OpKind::Relu, &[t1], "branch1.relu");
        let t2 = g.add(OpKind::Tanh, &[m], "branch2.tanh");
        let r2 = g.add(OpKind::Relu, &[t2], "branch2.relu");
        let s = g.add(OpKind::Add, &[r1, r2], "combine");
        g.add(OpKind::Output, &[s], "out");
        let mut p = Program::new(g);
        feed_x(&mut p, &[32, 32]);
        let f = Harness::new(p).lint();
        let cse: Vec<_> = f.iter().filter(|f| f.rule == "cse-duplicate").collect();
        // the relu bucket's bypass also drops t2, whose only consumer
        // was the bypassed duplicate; the shared trunk stays
        let relu = cse.iter().find(|f| f.nodes.contains(&r1)).expect("relu bucket");
        assert_eq!(relu.nodes, vec![r1, t2, r2]);
        assert_eq!(
            relu.steps,
            vec![
                RewriteStep::Bypass { node: r2, replacement: r1 },
                RewriteStep::Remove { node: t2 },
            ]
        );
        let tanh = cse.iter().find(|f| f.nodes.contains(&t1)).expect("tanh bucket");
        assert_eq!(tanh.steps, vec![RewriteStep::Bypass { node: t2, replacement: t1 }]);
        assert!(relu.est_wasted_j > tanh.est_wasted_j, "cone cost must be included");
    }

    #[test]
    fn double_softmax_is_flagged_and_bypassed() {
        let mut g = Graph::new("resm");
        let x = g.add(OpKind::Input, &[], "x");
        let s1 = g.add(OpKind::Softmax, &[x], "probs");
        let s2 = g.add(OpKind::Softmax, &[s1], "probs.again");
        let r = g.add(OpKind::Relu, &[s2], "clamp"); // relu of softmax: fine
        g.add(OpKind::Output, &[r], "out");
        let mut p = Program::new(g);
        feed_x(&mut p, &[16, 64]);
        let f = Harness::new(p).lint();
        let idem: Vec<_> = f.iter().filter(|f| f.rule == "idempotent-op").collect();
        assert_eq!(idem.len(), 1);
        assert_eq!(idem[0].nodes, vec![s1, s2]);
        assert_eq!(idem[0].steps, vec![RewriteStep::Bypass { node: s2, replacement: s1 }]);
        assert!(idem[0].est_wasted_j > 0.0);
    }

    #[test]
    fn dead_weight_feed_is_flagged() {
        let mut g = Graph::new("dw");
        let x = g.add(OpKind::Input, &[], "x");
        let w = g.add(OpKind::Weight, &[], "proj_w");
        let unused = g.add(OpKind::Weight, &[], "unused_bias");
        let m = g.add(OpKind::MatMul, &[x, w], "proj");
        g.add(OpKind::Output, &[m], "out");
        let mut p = Program::new(g);
        p.feed(0, Tensor::zeros(&[8, 16]));
        p.feed(1, Tensor::zeros(&[16, 4]));
        p.feed(2, Tensor::zeros(&[4]));
        let f = Harness::new(p).lint();
        let dw: Vec<_> = f.iter().filter(|f| f.rule == "dead-weight").collect();
        assert_eq!(dw.len(), 1);
        assert_eq!(dw[0].label, "unused_bias");
        assert_eq!(dw[0].steps, vec![RewriteStep::Remove { node: unused }]);
        assert!(dw[0].suggestion.contains("4 elements"));
    }

    #[test]
    fn tf32_unset_matmul_is_downcast_flagged() {
        let mut g = Graph::new("tf32");
        let x = g.add(OpKind::Input, &[], "x");
        let w = g.add(OpKind::Weight, &[], "w");
        let m = g.add(OpKind::MatMul, &[x, w], "proj");
        g.add(OpKind::Output, &[m], "out");
        let mut p = Program::new(g);
        p.feed(0, Tensor::zeros(&[64, 128]));
        p.feed(1, Tensor::zeros(&[128, 64]));
        let mut h = Harness::new(p);
        h.dispatcher =
            Dispatcher::new().register("matmul", crate::systems::torch_matmul_routine());
        let f = h.lint();
        let dc: Vec<_> = f.iter().filter(|f| f.rule == "dtype-downcast").collect();
        assert_eq!(dc.len(), 1, "findings: {f:?}");
        assert_eq!(dc[0].nodes, vec![m]);
        assert!(dc[0].est_wasted_j > 0.0);
        // the finding names the responsible flag and the cheaper assignment
        assert!(dc[0].suggestion.contains("torch.backends.cuda.matmul.allow_tf32"));
        assert!(dc[0].suggestion.contains("allow_tf32=true"));
        assert_eq!(
            dc[0].steps,
            vec![RewriteStep::SetAttr {
                node: m,
                key: "allow_tf32".into(),
                value: "true".into()
            }]
        );
        // with the flag already set the routine picks tensor cores: quiet
        h.env = Env::new().with("allow_tf32", "true");
        assert!(h.lint().iter().all(|f| f.rule != "dtype-downcast"));
    }

    #[test]
    fn unrequested_fused_kernel_is_advised() {
        let mut g = Graph::new("da");
        let x = g.add(OpKind::Input, &[], "x");
        let w = g.add(OpKind::Weight, &[], "w");
        let m = g.add(OpKind::MatMul, &[x, w], "proj");
        g.add(OpKind::Output, &[m], "out");
        let mut p = Program::new(g);
        p.feed(0, Tensor::zeros(&[8, 8]));
        p.feed(1, Tensor::zeros(&[8, 8]));
        let mut h = Harness::new(p);
        h.env = Env::new().with("allow_tf32", "true");
        h.dispatcher = Dispatcher::new()
            // requested via the op-name fallback: not reported
            .register("matmul", crate::systems::torch_matmul_routine())
            // registered, never requested, relevant to a present op
            .register("sys.fused_matmul", crate::systems::torch_matmul_routine())
            // registered, never requested, but no related op present
            .register(
                "sys.fused_count",
                crate::systems::frameworks::tf_count_nonzero_routine(),
            );
        let f = h.lint();
        let da: Vec<_> = f.iter().filter(|f| f.rule == "dispatch-attr").collect();
        assert_eq!(da.len(), 1, "findings: {f:?}");
        assert_eq!(da[0].label, "sys.fused_matmul");
        assert!(da[0].suggestion.contains("dispatch=\"sys.fused_matmul\""));
    }
}
