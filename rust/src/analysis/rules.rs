//! The initial lint rule set, grounded in the paper's root-cause
//! taxonomy (§6): redundant operations (dead subgraphs, duplicated
//! subexpressions, layout round-trips, redundant copies, materialised
//! broadcast expansion, redundant synchronisation), API misuse (unfused
//! matmul+add), and algebraic no-ops that cost a kernel launch for
//! identity math. Each rule reports the nodes involved, the joules the
//! executor would bill for them, and — where the fix is mechanical — a
//! rewrite that [`super::rewrite::apply_rewrite`] can perform.

use std::collections::BTreeMap;

use crate::graph::{NodeId, OpKind};

use super::{attr_csv, attr_f64, attr_usize, LintContext, LintFinding, LintPass, RewriteStep, Severity};

/// The default rule set, in stable order.
pub fn default_passes() -> Vec<Box<dyn LintPass>> {
    vec![
        Box::new(DeadSubgraph),
        Box::new(CseDuplicate),
        Box::new(AlgebraicNoop),
        Box::new(RedundantCopy),
        Box::new(LayoutRoundtrip),
        Box::new(ConcatSplitRoundtrip),
        Box::new(RepeatBroadcast),
        Box::new(UnfusedMatmulAdd),
        Box::new(RedundantSync),
    ]
}

// ---------------------------------------------------------------------
// dead-subgraph
// ---------------------------------------------------------------------

/// Nodes that reach no `Output`: the executor still runs and bills them
/// (it walks construction order, not liveness).
pub struct DeadSubgraph;

impl LintPass for DeadSubgraph {
    fn name(&self) -> &'static str {
        "dead-subgraph"
    }

    fn run(&self, cx: &LintContext) -> Vec<LintFinding> {
        let g = cx.graph;
        let outputs: Vec<NodeId> =
            g.nodes.iter().filter(|n| n.op == OpKind::Output).map(|n| n.id).collect();
        if outputs.is_empty() {
            return vec![]; // output-less graphs have no liveness notion
        }
        let mut live = vec![false; g.len()];
        for &o in &outputs {
            for (id, reach) in g.reaching(o).into_iter().enumerate() {
                live[id] = live[id] || reach;
            }
        }
        let dead: Vec<NodeId> = (0..g.len()).filter(|&id| !live[id]).collect();
        if dead.is_empty() {
            return vec![];
        }
        let est: f64 = dead.iter().map(|&id| cx.cost_j(id)).sum();
        // representative site: the most expensive dead node
        let top = dead
            .iter()
            .copied()
            .max_by(|&a, &b| cx.cost_j(a).total_cmp(&cx.cost_j(b)).then(b.cmp(&a)))
            .expect("non-empty");
        vec![LintFinding {
            rule: "dead-subgraph",
            severity: Severity::Warn,
            nodes: dead.clone(),
            label: g.nodes[top].label.clone(),
            est_wasted_j: est,
            suggestion: format!(
                "{} node(s) never reach an Output but are still executed and billed; \
                 delete the dead subgraph",
                dead.len()
            ),
            steps: dead.iter().map(|&node| RewriteStep::Remove { node }).collect(),
        }]
    }
}

// ---------------------------------------------------------------------
// cse-duplicate
// ---------------------------------------------------------------------

/// Structurally identical subtrees computed more than once: bucket the
/// subtree hashes and point every duplicate at the first occurrence.
pub struct CseDuplicate;

impl LintPass for CseDuplicate {
    fn name(&self) -> &'static str {
        "cse-duplicate"
    }

    fn run(&self, cx: &LintContext) -> Vec<LintFinding> {
        let g = cx.graph;
        let mut buckets: BTreeMap<u64, Vec<NodeId>> = BTreeMap::new();
        for node in &g.nodes {
            if node.op.is_virtual() || node.inputs.is_empty() {
                continue;
            }
            buckets.entry(cx.hashes[node.id]).or_default().push(node.id);
        }
        let mut out = Vec::new();
        for (_, ids) in buckets {
            if ids.len() < 2 {
                continue;
            }
            let canon = ids[0];
            // hash-collision paranoia: duplicates must agree on op + shape
            let dups: Vec<NodeId> = ids[1..]
                .iter()
                .copied()
                .filter(|&d| {
                    g.nodes[d].op == g.nodes[canon].op && cx.shapes[d] == cx.shapes[canon]
                })
                .collect();
            if dups.is_empty() {
                continue;
            }
            let est: f64 = dups.iter().map(|&d| cx.cost_j(d)).sum();
            let mut nodes = vec![canon];
            nodes.extend(&dups);
            out.push(LintFinding {
                rule: "cse-duplicate",
                severity: Severity::Warn,
                nodes,
                label: g.nodes[canon].label.clone(),
                est_wasted_j: est,
                suggestion: format!(
                    "{} duplicate(s) of `{}` recompute an identical subtree; reuse its \
                     output",
                    dups.len(),
                    g.nodes[canon].label
                ),
                steps: dups
                    .iter()
                    .map(|&d| RewriteStep::Bypass { node: d, replacement: canon })
                    .collect(),
            });
        }
        out
    }
}

// ---------------------------------------------------------------------
// algebraic-noop
// ---------------------------------------------------------------------

/// Identity math that still launches a kernel: `Scale(1)`, `Pow(1)`,
/// `Contiguous` straight after `Contiguous`, back-to-back `Copy`.
pub struct AlgebraicNoop;

impl LintPass for AlgebraicNoop {
    fn name(&self) -> &'static str {
        "algebraic-noop"
    }

    fn run(&self, cx: &LintContext) -> Vec<LintFinding> {
        let g = cx.graph;
        let mut out = Vec::new();
        for node in &g.nodes {
            let input_op = node.inputs.first().map(|&i| g.nodes[i].op);
            let reason = match node.op {
                OpKind::Scale if attr_f64(&node.attrs, "s", 1.0) == 1.0 => "scale by 1.0",
                OpKind::Pow if attr_f64(&node.attrs, "p", 2.0) == 1.0 => "pow with exponent 1.0",
                OpKind::Contiguous if input_op == Some(OpKind::Contiguous) => {
                    "contiguous of an already-contiguous tensor"
                }
                OpKind::Copy if input_op == Some(OpKind::Copy) => "copy of a fresh copy",
                _ => continue,
            };
            out.push(LintFinding {
                rule: "algebraic-noop",
                severity: Severity::Warn,
                nodes: vec![node.id],
                label: node.label.clone(),
                est_wasted_j: cx.cost_j(node.id),
                suggestion: format!("`{}` is a no-op ({reason}); drop it", node.label),
                steps: vec![RewriteStep::Bypass { node: node.id, replacement: node.inputs[0] }],
            });
        }
        out
    }
}

// ---------------------------------------------------------------------
// redundant-copy
// ---------------------------------------------------------------------

/// `Copy` of a source tensor (`Input`/`Weight`): the buffer is already
/// resident — the copy is pure HBM traffic (case c2's kv-cache copy).
pub struct RedundantCopy;

impl LintPass for RedundantCopy {
    fn name(&self) -> &'static str {
        "redundant-copy"
    }

    fn run(&self, cx: &LintContext) -> Vec<LintFinding> {
        let g = cx.graph;
        let mut out = Vec::new();
        for node in &g.nodes {
            if node.op != OpKind::Copy {
                continue;
            }
            let src = match node.inputs.first() {
                Some(&i) => i,
                None => continue,
            };
            if !matches!(g.nodes[src].op, OpKind::Input | OpKind::Weight) {
                continue;
            }
            out.push(LintFinding {
                rule: "redundant-copy",
                severity: Severity::Warn,
                nodes: vec![node.id],
                label: node.label.clone(),
                est_wasted_j: cx.cost_j(node.id),
                suggestion: format!(
                    "`{}` copies the already-resident source `{}`; read it in place \
                     (e.g. pass an aligned layout so no staging copy is needed)",
                    node.label, g.nodes[src].label
                ),
                steps: vec![RewriteStep::Bypass { node: node.id, replacement: src }],
            });
        }
        out
    }
}

// ---------------------------------------------------------------------
// layout-roundtrip
// ---------------------------------------------------------------------

/// `Permute → Contiguous → Permute → Contiguous` where the two permutes
/// compose to the identity: two materialised copies for a tensor that
/// ends up exactly where it started (case c5's default-format round
/// trip).
pub struct LayoutRoundtrip;

impl LintPass for LayoutRoundtrip {
    fn name(&self) -> &'static str {
        "layout-roundtrip"
    }

    fn run(&self, cx: &LintContext) -> Vec<LintFinding> {
        let g = cx.graph;
        let mut out = Vec::new();
        for node in &g.nodes {
            // anchor at the trailing Contiguous of the round trip
            let c2 = node;
            if c2.op != OpKind::Contiguous {
                continue;
            }
            let p2 = match c2.inputs.first().map(|&i| &g.nodes[i]) {
                Some(n) if n.op == OpKind::Permute => n,
                _ => continue,
            };
            let c1 = match p2.inputs.first().map(|&i| &g.nodes[i]) {
                Some(n) if n.op == OpKind::Contiguous => n,
                _ => continue,
            };
            let p1 = match c1.inputs.first().map(|&i| &g.nodes[i]) {
                Some(n) if n.op == OpKind::Permute => n,
                _ => continue,
            };
            // the interior of the chain must have no other consumers
            if cx.consumers[p2.id] != [c2.id]
                || cx.consumers[c1.id] != [p2.id]
                || cx.consumers[p1.id] != [c1.id]
            {
                continue;
            }
            let (perm1, perm2) = match (attr_csv(&p1.attrs, "perm"), attr_csv(&p2.attrs, "perm")) {
                (Some(a), Some(b)) if a.len() == b.len() => (a, b),
                _ => continue,
            };
            let identity = perm2.iter().enumerate().all(|(i, &p)| perm1.get(p) == Some(&i));
            if !identity {
                continue;
            }
            let src = match p1.inputs.first() {
                Some(&i) => i,
                None => continue,
            };
            let est = cx.cost_j(c1.id) + cx.cost_j(c2.id);
            out.push(LintFinding {
                rule: "layout-roundtrip",
                severity: Severity::Warn,
                nodes: vec![p1.id, c1.id, p2.id, c2.id],
                label: c2.label.clone(),
                est_wasted_j: est,
                suggestion: format!(
                    "`{}` permutes, materialises, permutes back, and materialises again — \
                     an identity round trip costing two full copies; keep `{}`'s layout",
                    c2.label, g.nodes[src].label
                ),
                steps: vec![
                    RewriteStep::Bypass { node: c2.id, replacement: src },
                    RewriteStep::Remove { node: p2.id },
                    RewriteStep::Remove { node: c1.id },
                    RewriteStep::Remove { node: p1.id },
                ],
            });
        }
        out
    }
}

// ---------------------------------------------------------------------
// concat-split-roundtrip
// ---------------------------------------------------------------------

/// `Concat` whose only consumers split it straight back into the
/// original parts (case c7's skip-connection concat/chunk round trip).
pub struct ConcatSplitRoundtrip;

impl LintPass for ConcatSplitRoundtrip {
    fn name(&self) -> &'static str {
        "concat-split-roundtrip"
    }

    fn run(&self, cx: &LintContext) -> Vec<LintFinding> {
        let g = cx.graph;
        let mut out = Vec::new();
        for node in &g.nodes {
            if node.op != OpKind::Concat || node.inputs.is_empty() {
                continue;
            }
            let dim = attr_usize(&node.attrs, "dim", 0);
            let splits = &cx.consumers[node.id];
            if splits.is_empty() {
                continue;
            }
            // every consumer must be an even SplitChunk along the same
            // dim with as many chunks as the concat has inputs
            let k = node.inputs.len();
            if !splits.iter().all(|&s| {
                let sn = &g.nodes[s];
                sn.op == OpKind::SplitChunk
                    && attr_usize(&sn.attrs, "dim", 0) == dim
                    && attr_usize(&sn.attrs, "chunks", 1) == k
                    && attr_usize(&sn.attrs, "index", 0) < k
            }) {
                continue;
            }
            // chunks are equal-sized only if every part has the same
            // extent along `dim`
            let part = match cx.shapes[node.inputs[0]].as_ref().and_then(|s| s.get(dim)) {
                Some(&d) => d,
                None => continue,
            };
            if !node.inputs.iter().all(|&i| {
                cx.shapes[i].as_ref().and_then(|s| s.get(dim)) == Some(&part)
            }) {
                continue;
            }
            let est =
                cx.cost_j(node.id) + splits.iter().map(|&s| cx.cost_j(s)).sum::<f64>();
            let mut nodes = vec![node.id];
            nodes.extend(splits.iter().copied());
            nodes.sort_unstable();
            let mut steps: Vec<RewriteStep> = splits
                .iter()
                .map(|&s| {
                    let idx = attr_usize(&g.nodes[s].attrs, "index", 0);
                    RewriteStep::Bypass { node: s, replacement: node.inputs[idx] }
                })
                .collect();
            steps.push(RewriteStep::Remove { node: node.id });
            out.push(LintFinding {
                rule: "concat-split-roundtrip",
                severity: Severity::Warn,
                nodes,
                label: node.label.clone(),
                est_wasted_j: est,
                suggestion: format!(
                    "`{}` concatenates {} tensors only to split them straight back; use \
                     the original tensors directly",
                    node.label, k
                ),
                steps,
            });
        }
        out
    }
}

// ---------------------------------------------------------------------
// repeat-broadcast
// ---------------------------------------------------------------------

/// Materialised `RepeatInterleave` feeding an op that can broadcast the
/// expansion itself — the paper's flagship redundant-operation case
/// (c4's GQA head expansion): the attention kernel takes `gqa_reps` and
/// expands in-kernel for free.
pub struct RepeatBroadcast;

impl LintPass for RepeatBroadcast {
    fn name(&self) -> &'static str {
        "repeat-broadcast"
    }

    fn run(&self, cx: &LintContext) -> Vec<LintFinding> {
        let g = cx.graph;
        let mut out = Vec::new();
        // (a) rewritable: repeats whose sole consumer is an Attention
        // that does not already expand in-kernel
        for attn in &g.nodes {
            if attn.op != OpKind::Attention || attr_usize(&attn.attrs, "gqa_reps", 1) > 1 {
                continue;
            }
            let reps_nodes: Vec<NodeId> = attn
                .inputs
                .iter()
                .copied()
                .filter(|&i| {
                    g.nodes[i].op == OpKind::RepeatInterleave
                        && attr_usize(&g.nodes[i].attrs, "reps", 1) > 1
                        && cx.consumers[i] == [attn.id]
                })
                .collect();
            if reps_nodes.is_empty() {
                continue;
            }
            let reps = attr_usize(&g.nodes[reps_nodes[0]].attrs, "reps", 1);
            if !reps_nodes
                .iter()
                .all(|&r| attr_usize(&g.nodes[r].attrs, "reps", 1) == reps)
            {
                continue; // mixed factors cannot fold into one gqa_reps
            }
            let est: f64 = reps_nodes.iter().map(|&r| cx.cost_j(r)).sum();
            let mut nodes = reps_nodes.clone();
            nodes.push(attn.id);
            nodes.sort_unstable();
            let mut steps: Vec<RewriteStep> = reps_nodes
                .iter()
                .map(|&r| RewriteStep::Bypass { node: r, replacement: g.nodes[r].inputs[0] })
                .collect();
            steps.push(RewriteStep::SetAttr {
                node: attn.id,
                key: "gqa_reps".into(),
                value: reps.to_string(),
            });
            out.push(LintFinding {
                rule: "repeat-broadcast",
                severity: Severity::Warn,
                nodes,
                label: g.nodes[reps_nodes[0]].label.clone(),
                est_wasted_j: est,
                suggestion: format!(
                    "`{}` materialises a {reps}x head expansion that `{}` can broadcast \
                     in-kernel; pass gqa_reps={reps} instead",
                    g.nodes[reps_nodes[0]].label, attn.label
                ),
                steps,
            });
        }
        // (b) advisory: repeats feeding only broadcast-capable
        // elementwise ops (no mechanical rewrite: the operand would need
        // a singleton dim for broadcasting to kick in)
        for node in &g.nodes {
            if node.op != OpKind::RepeatInterleave
                || attr_usize(&node.attrs, "reps", 1) <= 1
                || cx.consumers[node.id].is_empty()
            {
                continue;
            }
            let all_elementwise = cx.consumers[node.id].iter().all(|&c| {
                matches!(g.nodes[c].op, OpKind::Add | OpKind::Sub | OpKind::Mul | OpKind::Div)
            });
            if !all_elementwise {
                continue;
            }
            out.push(LintFinding {
                rule: "repeat-broadcast",
                severity: Severity::Info,
                nodes: vec![node.id],
                label: node.label.clone(),
                est_wasted_j: cx.cost_j(node.id),
                suggestion: format!(
                    "`{}` materialises a repeat that only feeds elementwise ops; a \
                     broadcastable view (singleton dim) would avoid the copy",
                    node.label
                ),
                steps: vec![],
            });
        }
        out
    }
}

// ---------------------------------------------------------------------
// unfused-matmul-add
// ---------------------------------------------------------------------

/// `MatMul` whose only consumer adds a bias: a fused `AddMm` saves the
/// intermediate's HBM round trip and a launch. Reported only when the
/// target's own dispatcher prices the fused kernel cheaper (a system
/// with a power-hungry addmm epilogue, case c10, would not benefit).
pub struct UnfusedMatmulAdd;

impl LintPass for UnfusedMatmulAdd {
    fn name(&self) -> &'static str {
        "unfused-matmul-add"
    }

    fn run(&self, cx: &LintContext) -> Vec<LintFinding> {
        let g = cx.graph;
        let mut out = Vec::new();
        for mm in &g.nodes {
            if mm.op != OpKind::MatMul || cx.consumers[mm.id].len() != 1 {
                continue;
            }
            let add = &g.nodes[cx.consumers[mm.id][0]];
            if add.op != OpKind::Add || add.inputs.len() != 2 {
                continue;
            }
            let bias = match add.inputs.iter().copied().find(|&i| i != mm.id) {
                Some(b) => b,
                None => continue, // add(m, m) is not a bias pattern
            };
            let (x, w) = match (mm.inputs.first(), mm.inputs.get(1)) {
                (Some(&x), Some(&w)) => (x, w),
                _ => continue,
            };
            let shapes = |ids: &[NodeId]| -> Option<Vec<Vec<usize>>> {
                ids.iter().map(|&i| cx.shapes[i].clone()).collect()
            };
            let (in_shapes, out_shape) = match (shapes(&[bias, x, w]), cx.shapes[add.id].clone()) {
                (Some(i), Some(o)) => (i, o),
                _ => continue,
            };
            let fused = cx.op_cost(OpKind::AddMm, &Default::default(), &in_shapes, &out_shape);
            let est = cx.cost_j(mm.id) + cx.cost_j(add.id) - fused.energy_j;
            if est <= 0.0 {
                continue; // fusion would not pay on this dispatcher
            }
            out.push(LintFinding {
                rule: "unfused-matmul-add",
                severity: Severity::Info,
                nodes: vec![mm.id, add.id],
                label: mm.label.clone(),
                est_wasted_j: est,
                suggestion: format!(
                    "`{}` + `{}` round-trip the GEMM output through HBM; a fused addmm \
                     kernel saves the intermediate",
                    mm.label, add.label
                ),
                steps: vec![RewriteStep::FuseAddMm { mm: mm.id, add: add.id }],
            });
        }
        out
    }
}

// ---------------------------------------------------------------------
// redundant-sync
// ---------------------------------------------------------------------

/// A `Barrier` that dominates no `AllReduce`: nothing downstream needs
/// the rendezvous, so the GPU spins near base power for nothing (case
/// c9's `dist.Join` busy-wait after the collective already finished).
pub struct RedundantSync;

impl LintPass for RedundantSync {
    fn name(&self) -> &'static str {
        "redundant-sync"
    }

    fn run(&self, cx: &LintContext) -> Vec<LintFinding> {
        let g = cx.graph;
        let mut out = Vec::new();
        for node in &g.nodes {
            if node.op != OpKind::Barrier {
                continue;
            }
            let guards_collective = g.nodes.iter().any(|n| {
                n.op == OpKind::AllReduce && n.id != node.id && cx.dom.dom.dominates(node.id, n.id)
            });
            if guards_collective {
                continue;
            }
            let steps = match node.inputs.first() {
                Some(&i) => vec![RewriteStep::Bypass { node: node.id, replacement: i }],
                None => vec![RewriteStep::Remove { node: node.id }],
            };
            out.push(LintFinding {
                rule: "redundant-sync",
                severity: Severity::Warn,
                nodes: vec![node.id],
                label: node.label.clone(),
                est_wasted_j: cx.cost_j(node.id),
                suggestion: format!(
                    "`{}` gates no collective (it dominates no all_reduce); the busy-wait \
                     burns power for nothing — drop the barrier or use an event wait",
                    node.label
                ),
                steps,
            });
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dispatch::Env;
    use crate::energy::DeviceSpec;
    use crate::exec::{Dispatcher, Program};
    use crate::graph::Graph;
    use crate::tensor::Tensor;

    struct Harness {
        prog: Program,
        dispatcher: Dispatcher,
        env: Env,
        device: DeviceSpec,
    }

    impl Harness {
        fn new(prog: Program) -> Harness {
            Harness {
                prog,
                dispatcher: Dispatcher::new(),
                env: Env::new(),
                device: DeviceSpec::h200_sim(),
            }
        }

        fn lint(&self) -> Vec<LintFinding> {
            let cx =
                LintContext::new(&self.prog, &self.dispatcher, &self.env, &self.device).unwrap();
            super::super::lint_graph(&cx)
        }
    }

    fn feed_x(p: &mut Program, shape: &[usize]) {
        p.feed(0, Tensor::zeros(shape));
    }

    #[test]
    fn dead_subgraph_is_found_and_costed() {
        let mut g = Graph::new("dead");
        let x = g.add(OpKind::Input, &[], "x");
        let live = g.add(OpKind::Gelu, &[x], "live");
        let dead = g.add(OpKind::Tanh, &[x], "dead.branch");
        let dead2 = g.add(OpKind::Gelu, &[dead], "dead.tip");
        g.add(OpKind::Output, &[live], "out");
        let mut p = Program::new(g);
        feed_x(&mut p, &[64, 64]);
        let h = Harness::new(p);
        let f = h.lint();
        let dead_f: Vec<_> = f.iter().filter(|f| f.rule == "dead-subgraph").collect();
        assert_eq!(dead_f.len(), 1);
        assert_eq!(dead_f[0].nodes, vec![dead, dead2]);
        assert!(dead_f[0].est_wasted_j > 0.0);
    }

    #[test]
    fn cse_duplicates_bucket_together() {
        let mut g = Graph::new("cse");
        let x = g.add(OpKind::Input, &[], "x");
        let a = g.add(OpKind::Gelu, &[x], "act.a");
        let b = g.add(OpKind::Gelu, &[x], "act.b"); // duplicate of a
        let s = g.add(OpKind::Add, &[a, b], "sum");
        g.add(OpKind::Output, &[s], "out");
        let mut p = Program::new(g);
        feed_x(&mut p, &[32, 32]);
        let f = Harness::new(p).lint();
        let cse: Vec<_> = f.iter().filter(|f| f.rule == "cse-duplicate").collect();
        assert_eq!(cse.len(), 1);
        assert_eq!(cse[0].nodes, vec![a, b]);
        assert_eq!(cse[0].steps, vec![RewriteStep::Bypass { node: b, replacement: a }]);
    }

    #[test]
    fn algebraic_noops_scale_pow_contiguous() {
        let mut g = Graph::new("noop");
        let x = g.add(OpKind::Input, &[], "x");
        let s1 = g.add_attr1(OpKind::Scale, &[x], "scale.one", "s", "1.0");
        let p1 = g.add_attr1(OpKind::Pow, &[s1], "pow.one", "p", "1");
        let c1 = g.add(OpKind::Contiguous, &[p1], "contig.a");
        let c2 = g.add(OpKind::Contiguous, &[c1], "contig.b");
        g.add(OpKind::Output, &[c2], "out");
        let mut p = Program::new(g);
        feed_x(&mut p, &[16, 16]);
        let f = Harness::new(p).lint();
        let noops: Vec<&str> = f
            .iter()
            .filter(|f| f.rule == "algebraic-noop")
            .map(|f| f.label.as_str())
            .collect();
        assert!(noops.contains(&"scale.one"));
        assert!(noops.contains(&"pow.one"));
        assert!(noops.contains(&"contig.b"));
        assert!(!noops.contains(&"contig.a"), "first contiguous is not a no-op");
        // a real scale must not be flagged
        assert!(!f.iter().any(|f| f.label == "scale.half"));
    }

    #[test]
    fn scale_with_real_factor_not_flagged() {
        let mut g = Graph::new("ok");
        let x = g.add(OpKind::Input, &[], "x");
        let s = g.add_attr1(OpKind::Scale, &[x], "scale.half", "s", "0.5");
        g.add(OpKind::Output, &[s], "out");
        let mut p = Program::new(g);
        feed_x(&mut p, &[8]);
        let f = Harness::new(p).lint();
        assert!(!f.iter().any(|f| f.rule == "algebraic-noop"));
    }

    #[test]
    fn layout_roundtrip_identity_perms_only() {
        let build = |perm2: &str| {
            let mut g = Graph::new("rt");
            let x = g.add(OpKind::Input, &[], "x");
            let p1 = g.add_attr1(OpKind::Permute, &[x], "to_hnd", "perm", "0,2,1,3");
            let c1 = g.add(OpKind::Contiguous, &[p1], "fmt_copy");
            let p2 = g.add_attr1(OpKind::Permute, &[c1], "back", "perm", perm2);
            let c2 = g.add(OpKind::Contiguous, &[p2], "fmt_copy2");
            g.add(OpKind::Output, &[c2], "out");
            let mut p = Program::new(g);
            feed_x(&mut p, &[2, 4, 8, 16]);
            Harness::new(p).lint()
        };
        let f = build("0,2,1,3"); // involution: identity round trip
        let rt: Vec<_> = f.iter().filter(|f| f.rule == "layout-roundtrip").collect();
        assert_eq!(rt.len(), 1);
        assert_eq!(rt[0].nodes, vec![1, 2, 3, 4]);
        // a non-inverse second permute is NOT a round trip
        let f = build("0,3,1,2");
        assert!(!f.iter().any(|f| f.rule == "layout-roundtrip"));
    }

    #[test]
    fn barrier_guarding_collective_not_flagged() {
        let mut g = Graph::new("sync");
        let x = g.add(OpKind::Input, &[], "grads");
        let b = g.add(OpKind::Barrier, &[x], "pre.barrier");
        let ar = g.add(OpKind::AllReduce, &[b], "ddp.all_reduce");
        g.add(OpKind::Output, &[ar], "out");
        let mut p = Program::new(g);
        feed_x(&mut p, &[1024]);
        let f = Harness::new(p).lint();
        assert!(!f.iter().any(|f| f.rule == "redundant-sync"));
    }

    #[test]
    fn barrier_after_collective_is_flagged() {
        let mut g = Graph::new("sync2");
        let x = g.add(OpKind::Input, &[], "grads");
        let ar = g.add(OpKind::AllReduce, &[x], "ddp.all_reduce");
        let b = g.add(OpKind::Barrier, &[ar], "dist.Join.barrier");
        g.add(OpKind::Output, &[b], "out");
        let mut p = Program::new(g);
        feed_x(&mut p, &[1024]);
        let f = Harness::new(p).lint();
        let sync: Vec<_> = f.iter().filter(|f| f.rule == "redundant-sync").collect();
        assert_eq!(sync.len(), 1);
        assert_eq!(sync[0].nodes, vec![b]);
        assert!(sync[0].est_wasted_j > 0.0, "barrier busy-wait must carry a cost");
    }

    #[test]
    fn unfused_matmul_add_suggests_fusion() {
        let mut g = Graph::new("lin");
        let x = g.add(OpKind::Input, &[], "x");
        let w = g.add(OpKind::Weight, &[], "w");
        let bias = g.add(OpKind::Weight, &[], "b");
        let m = g.add(OpKind::MatMul, &[x, w], "lin.matmul");
        let a = g.add(OpKind::Add, &[m, bias], "lin.add_bias");
        g.add(OpKind::Output, &[a], "out");
        let mut p = Program::new(g);
        p.feed(0, Tensor::zeros(&[64, 128]));
        p.feed(1, Tensor::zeros(&[128, 32]));
        p.feed(2, Tensor::zeros(&[32]));
        let f = Harness::new(p).lint();
        let fused: Vec<_> = f.iter().filter(|f| f.rule == "unfused-matmul-add").collect();
        assert_eq!(fused.len(), 1);
        assert_eq!(fused[0].nodes, vec![m, a]);
        assert_eq!(fused[0].steps, vec![RewriteStep::FuseAddMm { mm: m, add: a }]);
        assert!(fused[0].est_wasted_j > 0.0);
    }

    #[test]
    fn repeat_into_attention_rewrites_to_gqa_attr() {
        let mut g = Graph::new("gqa");
        let q = g.add(OpKind::Input, &[], "q");
        let k = g.add(OpKind::Input, &[], "k");
        let v = g.add(OpKind::Input, &[], "v");
        let mut at = crate::graph::Attrs::new();
        at.insert("dim".into(), "2".into());
        at.insert("reps".into(), "2".into());
        let kr = g.add_attrs(OpKind::RepeatInterleave, &[k], "attn.k_repeat_interleave", at.clone());
        let vr = g.add_attrs(OpKind::RepeatInterleave, &[v], "attn.v_repeat_interleave", at);
        let mut aat = crate::graph::Attrs::new();
        aat.insert("layout".into(), "nhd".into());
        let attn = g.add_attrs(OpKind::Attention, &[q, kr, vr], "attn.flash", aat);
        g.add(OpKind::Output, &[attn], "out");
        let mut p = Program::new(g);
        p.feed(0, Tensor::zeros(&[1, 8, 4, 16]));
        p.feed(1, Tensor::zeros(&[1, 8, 2, 16]));
        p.feed(2, Tensor::zeros(&[1, 8, 2, 16]));
        let f = Harness::new(p).lint();
        let rb: Vec<_> = f.iter().filter(|f| f.rule == "repeat-broadcast").collect();
        assert_eq!(rb.len(), 1);
        assert_eq!(rb[0].nodes, vec![kr, vr, attn]);
        assert!(rb[0]
            .steps
            .contains(&RewriteStep::SetAttr { node: attn, key: "gqa_reps".into(), value: "2".into() }));
    }
}
