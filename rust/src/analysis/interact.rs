//! Joint config-space interaction search: minimal flag-set diagnosis
//! with dominance pruning.
//!
//! The symbolic dispatch pass (`dtype-downcast`) enumerates each config
//! flag independently, so it can never expose a flag *combination*
//! whose joint assignment dominates every single flip — exactly the
//! interaction class where `allow_tf32` only pays off together with a
//! layout flag. This module lifts [`Routine::enumerate_outcomes`] to
//! joint assignments over all config-sourced branch variables, kept
//! tractable by:
//!
//! 1. **Flag slicing** — only flags that reach a branch guarding a
//!    cost-divergent region enter the search. The reachability walk
//!    goes from each `source_of` config flag to the branches testing
//!    it and on to the launch sites they guard; a flag whose guarded
//!    launches are cost-uniform cannot change the bill and is pinned
//!    to its live value.
//! 2. **Branch-and-bound dominance pruning** — partial assignments are
//!    bounded optimistically by the cheapest kernel still reachable
//!    under [`Routine::reachable_choices`] (the monotone `KernelCost`
//!    lattice: freeing a flag can only grow the reachable set, so the
//!    bound is a true lower bound). A partial assignment whose bound
//!    already meets the incumbent is cut; visit/prune counters are
//!    exposed for benching.
//!
//! From the cheapest feasible joint outcome a **minimal diagnosis** is
//! extracted ddmin-style: flags whose removal does not lose the savings
//! are dropped until the set is 1-minimal (removing *any* remaining
//! flag loses the savings). Each diagnosis is emitted as an
//! `interaction` lint finding carrying one [`RewriteStep::SetAttr`] per
//! (node, flag), so `lint --verify` A/B-measures the joint flip through
//! the real executor end-to-end.
//!
//! The search is driven by the static cost model (the same
//! [`LintContext::op_cost`] path the other rules use), *not* by
//! measurement — `--verify` exists precisely to confirm a diagnosis
//! against a measured delta.

use std::collections::{BTreeMap, BTreeSet};

use crate::dispatch::{Env, Routine, Term, VarSource};
use crate::energy::{DeviceSpec, KernelCost, KernelDesc};
use crate::exec::counts;
use crate::graph::{NodeId, OpKind};
use crate::tensor::Tensor;
use crate::util::pool::par_map;

use super::suite::{LintTarget, TargetReport};
use super::{sort_findings, LintContext, LintFinding, RewriteStep, Severity};

/// Budget knobs for the joint search.
#[derive(Clone, Copy, Debug)]
pub struct InteractConfig {
    /// Maximum number of sliced flags that enter one routine's joint
    /// search (the space is exponential in this). Surplus flags are
    /// pinned to their live values, in deterministic name order.
    pub max_joint_flags: usize,
}

impl Default for InteractConfig {
    fn default() -> InteractConfig {
        InteractConfig { max_joint_flags: 8 }
    }
}

/// Search-effort counters, exposed so the bench can assert that
/// dominance pruning visits measurably fewer outcomes than exhaustive
/// enumeration.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SearchStats {
    /// Search-tree nodes expanded (partial assignments + leaves).
    pub visited: usize,
    /// Subtrees cut because their optimistic bound met the incumbent.
    pub pruned: usize,
    /// Full joint assignments actually evaluated.
    pub evaluated: usize,
    /// Leaves an exhaustive enumeration would evaluate.
    pub exhaustive: usize,
}

impl SearchStats {
    pub fn add(&mut self, other: &SearchStats) {
        self.visited += other.visited;
        self.pruned += other.pruned;
        self.evaluated += other.evaluated;
        self.exhaustive += other.exhaustive;
    }
}

/// One flag of a joint diagnosis, with the saving (or cost) the flag
/// flipped *alone* would produce — the marginal the renderer contrasts
/// against the joint saving.
#[derive(Clone, Debug)]
pub struct FlagMarginal {
    pub flag: String,
    pub value: String,
    /// Provenance description (`configuration flag \`...\``).
    pub source: String,
    /// Energy the lone flip saves; negative means it costs energy.
    pub saved_j: f64,
    /// Whether the lone flip stays within the current time budget.
    pub time_ok: bool,
}

/// A 1-minimal joint flag set that strictly saves energy at no time
/// cost, with the per-flag marginal breakdown.
#[derive(Clone, Debug)]
pub struct InteractionDiagnosis {
    /// Nodes the joint flip fixes, ascending.
    pub nodes: Vec<NodeId>,
    /// Representative site label (the biggest saver).
    pub label: String,
    /// The 1-minimal changed flags, sorted by name: flag → new value.
    pub assignment: Vec<(String, String)>,
    /// Joint saving summed over `nodes` (J).
    pub joint_saved_j: f64,
    pub kernel_now: String,
    pub kernel_then: String,
    /// One marginal per flag in `assignment`, summed over `nodes`.
    pub marginals: Vec<FlagMarginal>,
}

impl InteractionDiagnosis {
    /// The flag set as `flag=value, ...` — shared by the finding text
    /// and the report renderer.
    pub fn flag_set(&self) -> String {
        let parts: Vec<String> =
            self.assignment.iter().map(|(k, v)| format!("{k}={v}")).collect();
        parts.join(", ")
    }
}

/// Joint-search outcome for one node: the effort counters, plus the
/// accepted diagnosis when one exists.
#[derive(Clone, Debug)]
pub struct NodeSearch {
    pub node: NodeId,
    pub stats: SearchStats,
    pub hit: Option<NodeHit>,
}

/// One node's accepted joint flip (pre-grouping).
#[derive(Clone, Debug)]
pub struct NodeHit {
    /// 1-minimal changed flags, sorted by name.
    pub assignment: Vec<(String, String)>,
    pub saved_j: f64,
    pub kernel_now: String,
    pub kernel_then: String,
    /// Per-flag lone-flip marginals for this node.
    pub marginals: Vec<FlagMarginal>,
}

// ---------------------------------------------------------------------
// Per-choice cost table
// ---------------------------------------------------------------------

/// Cost of running `node`'s workload on one concrete [`KernelChoice`]
/// (mirrors [`LintContext::op_cost`] with the dispatch walk factored
/// out, so the branch-and-bound can price thousands of assignments
/// from a per-choice table instead of re-dispatching).
///
/// [`KernelChoice`]: crate::dispatch::KernelChoice
fn choice_costs(
    cx: &LintContext,
    routine: &Routine,
    flops: f64,
    bytes: f64,
    n_launches: usize,
) -> Vec<KernelCost> {
    routine
        .choices
        .iter()
        .map(|choice| {
            let desc = KernelDesc {
                name: choice.kernel.clone(),
                unit: choice.unit,
                flops,
                bytes: bytes * choice.bytes_mult,
                efficiency: choice.efficiency,
                time_mult: choice.time_mult,
                fixed_time_us: 0.0,
                fixed_power_w: 0.0,
            };
            let mut cost = desc.cost(cx.device);
            if n_launches > 1 {
                let extra = (n_launches - 1) as f64 * cx.device.launch_overhead_us;
                cost.time_us += extra;
                cost.energy_j += extra * 1e-6 * cx.device.base_w;
                cost.avg_power_w = (cost.energy_j / (cost.time_us * 1e-6)).min(cx.device.max_w);
                cost.energy_j = cost.energy_j.min(cost.avg_power_w * cost.time_us * 1e-6);
            }
            cost
        })
        .collect()
}

// ---------------------------------------------------------------------
// Flag slicing
// ---------------------------------------------------------------------

/// Launch indices reachable from `start` with every branch free.
fn reachable_from(routine: &Routine, start: usize) -> BTreeSet<usize> {
    let mut reachable = BTreeSet::new();
    let mut seen = vec![false; routine.blocks.len()];
    let mut work = vec![start];
    while let Some(bb) = work.pop() {
        if seen[bb] {
            continue;
        }
        seen[bb] = true;
        match &routine.blocks[bb].term {
            Term::CondBranch { then_bb, else_bb, .. } => {
                work.push(*then_bb);
                work.push(*else_bb);
            }
            Term::Switch { arms, default_bb, .. } => {
                work.push(*default_bb);
                for &(_, b) in arms {
                    work.push(b);
                }
            }
            Term::Jump { bb: nxt } => work.push(*nxt),
            Term::Launch { idx } => {
                reachable.insert(*idx);
            }
        }
    }
    reachable
}

fn cost_bits(c: &KernelCost) -> (u64, u64) {
    (c.energy_j.to_bits(), c.time_us.to_bits())
}

/// Does any branch testing `var` guard a cost-divergent region? A flag
/// only influences execution through the branches that test it; if
/// every launch reachable from such a branch prices identically, the
/// flag cannot change the bill and is sliced out of the search.
fn guards_divergence(routine: &Routine, var: &str, costs: &[KernelCost]) -> bool {
    for block in &routine.blocks {
        let succs: Vec<usize> = match &block.term {
            Term::CondBranch { var: v, then_bb, else_bb, .. } if v == var => {
                vec![*then_bb, *else_bb]
            }
            Term::Switch { var: v, arms, default_bb } if v == var => {
                let mut s: Vec<usize> = arms.iter().map(|&(_, b)| b).collect();
                s.push(*default_bb);
                s
            }
            _ => continue,
        };
        let mut union = BTreeSet::new();
        for s in succs {
            union.extend(reachable_from(routine, s));
        }
        let mut it = union.iter();
        if let Some(&first) = it.next() {
            if it.any(|&i| cost_bits(&costs[i]) != cost_bits(&costs[first])) {
                return true;
            }
        }
    }
    false
}

/// The sliced search space: config-sourced flags guarding cost
/// divergence, each with its finite tested-literal-or-unset value
/// space, in deterministic name order.
fn sliced_flags(routine: &Routine, costs: &[KernelCost]) -> Vec<(String, Vec<String>)> {
    routine
        .branch_space()
        .into_iter()
        .filter(|(var, _)| {
            matches!(routine.source_of(var), Some(VarSource::ConfigFlag(_)))
                && guards_divergence(routine, var, costs)
        })
        .collect()
}

// ---------------------------------------------------------------------
// Branch-and-bound
// ---------------------------------------------------------------------

struct Bnb<'r> {
    routine: &'r Routine,
    costs: &'r [KernelCost],
    space: &'r [(String, Vec<String>)],
    /// Feasibility budget: the joint flip must not be slower than the
    /// kernel the node runs today.
    time_budget_us: f64,
    best_e: f64,
    best: Option<BTreeMap<String, String>>,
    stats: SearchStats,
}

impl Bnb<'_> {
    /// DFS over the sliced flags in order; `assigned` holds the pinned
    /// non-sliced variables plus every flag fixed so far.
    fn dfs(&mut self, depth: usize, assigned: &mut BTreeMap<String, String>) {
        self.stats.visited += 1;
        if depth == self.space.len() {
            self.stats.evaluated += 1;
            let idx = self.routine.launch_for(&Env { values: assigned.clone() });
            let c = &self.costs[idx];
            if c.time_us <= self.time_budget_us && c.energy_j < self.best_e {
                self.best_e = c.energy_j;
                let mut a = BTreeMap::new();
                for (var, _) in self.space {
                    a.insert(var.clone(), assigned[var].clone());
                }
                self.best = Some(a);
            }
            return;
        }
        // dominance bound: the cheapest kernel any completion of this
        // partial assignment could still launch
        let reach = self.routine.reachable_choices(assigned);
        let bound =
            reach.iter().map(|&i| self.costs[i].energy_j).fold(f64::INFINITY, f64::min);
        if bound >= self.best_e {
            self.stats.pruned += 1;
            return;
        }
        let (var, vals) = &self.space[depth];
        for v in vals {
            assigned.insert(var.clone(), v.clone());
            self.dfs(depth + 1, assigned);
        }
        assigned.remove(var);
    }
}

// ---------------------------------------------------------------------
// Per-node search + ddmin minimisation
// ---------------------------------------------------------------------

/// Joint config-space search over one node's dispatch routine. Returns
/// `None` when the node has no searchable config space (virtual,
/// costless, shape-unknown, or a routine without sliced flags);
/// otherwise the effort counters plus the accepted 1-minimal diagnosis
/// when the search found a strictly cheaper, no-slower joint flip.
pub fn search_node(cx: &LintContext, id: NodeId, cfg: &InteractConfig) -> Option<NodeSearch> {
    let node = cx.node(id);
    if node.op.is_virtual() || node.op == OpKind::Barrier || node.op == OpKind::Idle {
        return None;
    }
    let cur = &cx.cost[id];
    let (cur_e, cur_t) = (cur.energy_j, cur.time_us);
    if cur_e <= 0.0 {
        return None;
    }
    let out_shape = cx.shapes[id].as_ref()?.clone();
    let in_shapes: Option<Vec<Vec<usize>>> =
        node.inputs.iter().map(|&i| cx.shapes[i].clone()).collect();
    let in_shapes = in_shapes?;
    let key = node.attrs.get("dispatch").cloned().unwrap_or_else(|| node.op.name().to_string());
    let routine = cx.dispatcher.routine_for(node.op, &key);
    if routine.provenance.is_empty() {
        return None;
    }
    let merged = cx.env.merged(&node.attrs);

    // per-choice cost table (counts are flag-independent here; the
    // honest re-evaluation below goes through the full op_cost path)
    let ins: Vec<Tensor> = in_shapes.iter().map(|s| Tensor::zeros(s)).collect();
    let ins_ref: Vec<&Tensor> = ins.iter().collect();
    let out = Tensor::zeros(&out_shape);
    let (flops, bytes, n_launches) = counts::op_counts(node.op, &node.attrs, &ins_ref, &out);
    let costs = choice_costs(cx, &routine, flops, bytes, n_launches);

    let mut space = sliced_flags(&routine, &costs);
    if space.is_empty() {
        return None;
    }
    space.truncate(cfg.max_joint_flags);

    // pin every non-sliced branch variable to its live value
    let mut pinned = BTreeMap::new();
    for (var, _) in routine.branch_space() {
        if !space.iter().any(|(v, _)| *v == var) {
            pinned.insert(var.clone(), merged.get(&var).to_string());
        }
    }
    let exhaustive = space.iter().map(|(_, vs)| vs.len()).product();
    let mut bnb = Bnb {
        routine: &routine,
        costs: &costs,
        space: &space,
        time_budget_us: cur_t,
        best_e: f64::INFINITY,
        best: None,
        stats: SearchStats { exhaustive, ..SearchStats::default() },
    };
    let mut assigned = pinned;
    bnb.dfs(0, &mut assigned);
    let stats = bnb.stats;
    let mut result = NodeSearch { node: id, stats, hit: None };

    let best = match bnb.best {
        Some(b) => b,
        None => return Some(result),
    };
    // changed flags only: values already matching the live env are not
    // part of the diagnosis
    let mut diffs: Vec<(String, String)> =
        best.into_iter().filter(|(k, v)| merged.get(k) != v.as_str()).collect();
    if diffs.is_empty() {
        return Some(result);
    }
    // honest re-evaluation through the full dispatch path, exactly as
    // `--verify` will apply it (attrs override the env)
    let eval = |flags: &[(String, String)]| -> KernelCost {
        let mut attrs = node.attrs.clone();
        for (k, v) in flags {
            attrs.insert(k.clone(), v.clone());
        }
        cx.op_cost(node.op, &attrs, &in_shapes, &out_shape)
    };
    let mut cand = eval(&diffs);
    if !(cand.energy_j < cur_e && cand.time_us <= cur_t) {
        return Some(result);
    }
    // ddmin to a 1-minimal set: drop any flag whose removal keeps the
    // full savings; loop until no single removal survives
    loop {
        let mut dropped = false;
        for i in 0..diffs.len() {
            let mut sub = diffs.clone();
            sub.remove(i);
            let c = eval(&sub);
            if c.energy_j < cur_e && c.time_us <= cur_t && c.energy_j <= cand.energy_j {
                diffs = sub;
                cand = c;
                dropped = true;
                break;
            }
        }
        if !dropped {
            break;
        }
    }
    let marginals = diffs
        .iter()
        .map(|(k, v)| {
            let m = eval(std::slice::from_ref(&(k.clone(), v.clone())));
            FlagMarginal {
                flag: k.clone(),
                value: v.clone(),
                source: routine
                    .source_of(k)
                    .map(|s| s.describe())
                    .unwrap_or_else(|| format!("variable `{k}`")),
                saved_j: cur_e - m.energy_j,
                time_ok: m.time_us <= cur_t,
            }
        })
        .collect();
    let kernel_now = routine.run(&merged).choice.kernel;
    let kernel_then = {
        let mut env = merged.clone();
        for (k, v) in &diffs {
            env.set(k, v);
        }
        routine.run(&env).choice.kernel
    };
    result.hit = Some(NodeHit {
        assignment: diffs,
        saved_j: cur_e - cand.energy_j,
        kernel_now,
        kernel_then,
        marginals,
    });
    Some(result)
}

// ---------------------------------------------------------------------
// Graph + suite drivers
// ---------------------------------------------------------------------

/// Run the joint search over every node of one analysed graph, grouping
/// per-node hits that share the same 1-minimal flag set into one
/// diagnosis. Only genuine interactions (≥ 2 flags) become diagnoses —
/// single-flag flips are `dtype-downcast`'s territory.
pub fn joint_search(
    cx: &LintContext,
    cfg: &InteractConfig,
) -> (Vec<InteractionDiagnosis>, SearchStats) {
    let mut stats = SearchStats::default();
    let mut groups: BTreeMap<Vec<(String, String)>, Vec<(NodeId, NodeHit)>> = BTreeMap::new();
    for node in &cx.graph.nodes {
        if let Some(s) = search_node(cx, node.id, cfg) {
            stats.add(&s.stats);
            if let Some(hit) = s.hit {
                if hit.assignment.len() >= 2 {
                    groups.entry(hit.assignment.clone()).or_default().push((node.id, hit));
                }
            }
        }
    }
    let mut out = Vec::new();
    for (assignment, hits) in groups {
        let mut nodes: Vec<NodeId> = hits.iter().map(|(id, _)| *id).collect();
        nodes.sort_unstable();
        let joint_saved_j: f64 = hits.iter().map(|(_, h)| h.saved_j).sum();
        // representative site: biggest saver, lowest node id on ties
        let (top_id, top) = hits
            .iter()
            .max_by(|(na, a), (nb, b)| a.saved_j.total_cmp(&b.saved_j).then(nb.cmp(na)))
            .expect("non-empty group");
        let label = cx.node(*top_id).label.clone();
        // sum marginals flag-wise across the group's nodes
        let marginals = assignment
            .iter()
            .map(|(k, v)| {
                let per_node: Vec<&FlagMarginal> = hits
                    .iter()
                    .flat_map(|(_, h)| h.marginals.iter())
                    .filter(|m| m.flag == *k)
                    .collect();
                FlagMarginal {
                    flag: k.clone(),
                    value: v.clone(),
                    source: per_node
                        .first()
                        .map(|m| m.source.clone())
                        .unwrap_or_else(|| format!("variable `{k}`")),
                    saved_j: per_node.iter().map(|m| m.saved_j).sum(),
                    time_ok: per_node.iter().all(|m| m.time_ok),
                }
            })
            .collect();
        out.push(InteractionDiagnosis {
            nodes,
            label,
            assignment,
            joint_saved_j,
            kernel_now: top.kernel_now.clone(),
            kernel_then: top.kernel_then.clone(),
            marginals,
        });
    }
    out.sort_by(|a, b| b.joint_saved_j.total_cmp(&a.joint_saved_j).then(a.label.cmp(&b.label)));
    (out, stats)
}

/// One target's joint-search result.
#[derive(Clone, Debug)]
pub struct InteractReport {
    pub target: String,
    pub nodes: usize,
    pub static_j: f64,
    pub diagnoses: Vec<InteractionDiagnosis>,
    pub stats: SearchStats,
    /// Set when the target's graph failed validation/analysis.
    pub error: Option<String>,
}

/// Pseudo-target name an interaction report gates under
/// (manifest/`--target`), mirroring [`super::diff_name`].
pub fn interact_name(target: &str) -> String {
    format!("interact~{target}")
}

/// One `interaction` lint finding per diagnosis: the flag set, the
/// cheaper joint assignment, and one `SetAttr` per (node, flag) so the
/// A/B verifier measures the joint flip end-to-end.
pub fn interaction_finding(d: &InteractionDiagnosis) -> LintFinding {
    let set = d.flag_set();
    let steps = d
        .nodes
        .iter()
        .flat_map(|&node| {
            d.assignment.iter().map(move |(k, v)| RewriteStep::SetAttr {
                node,
                key: k.clone(),
                value: v.clone(),
            })
        })
        .collect();
    LintFinding {
        rule: "interaction",
        severity: Severity::Warn,
        nodes: d.nodes.clone(),
        label: d.label.clone(),
        est_wasted_j: d.joint_saved_j,
        suggestion: format!(
            "{} kernel(s) run {}; no single flag flip survives the energy+time gate, but \
             jointly setting {{{set}}} selects {} — a 1-minimal set of {} flags: strictly \
             less energy at no time cost, and removing any one flag loses the saving",
            d.nodes.len(),
            d.kernel_now,
            d.kernel_then,
            d.assignment.len(),
        ),
        steps,
    }
}

impl InteractReport {
    /// Diagnoses as ranked lint findings.
    pub fn findings(&self) -> Vec<LintFinding> {
        let mut out: Vec<LintFinding> = self.diagnoses.iter().map(interaction_finding).collect();
        sort_findings(&mut out);
        out
    }

    /// Repackage under the `interact~target` pseudo-target so
    /// `lint --expect` gates interactions with the same manifest
    /// machinery, and `render_lint` shows the marginal-vs-joint
    /// breakdown carried in `interactions`.
    pub fn to_target_report(&self) -> TargetReport {
        TargetReport {
            name: interact_name(&self.target),
            nodes: self.nodes,
            static_j: self.static_j,
            findings: self.findings(),
            error: self.error.clone(),
            interactions: self.diagnoses.clone(),
        }
    }
}

/// Joint search over one suite target.
pub fn interact_target(
    t: &LintTarget,
    device: &DeviceSpec,
    cfg: &InteractConfig,
) -> crate::Result<InteractReport> {
    let cx = LintContext::new(&t.run.prog, &t.run.dispatcher, &t.run.env, device)
        .map_err(|e| e.context(format!("interaction search target `{}`", t.name)))?;
    let (diagnoses, stats) = joint_search(&cx, cfg);
    Ok(InteractReport {
        target: t.name.clone(),
        nodes: t.run.prog.graph.len(),
        static_j: cx.total_static_j(),
        diagnoses,
        stats,
        error: None,
    })
}

/// Joint search over every suite target, fanning out across `threads`
/// workers. Per-target results are independent and fully ordered, so
/// the output is bit-identical for any worker count.
pub fn interact_suite(
    targets: &[LintTarget],
    device: &DeviceSpec,
    threads: usize,
    cfg: &InteractConfig,
) -> Vec<InteractReport> {
    par_map(targets, threads, |t| {
        interact_target(t, device, cfg).unwrap_or_else(|e| InteractReport {
            target: t.name.clone(),
            nodes: t.run.prog.graph.len(),
            static_j: 0.0,
            diagnoses: vec![],
            stats: SearchStats::default(),
            error: Some(e.to_string()),
        })
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dispatch::{Block, KernelChoice};
    use crate::energy::ComputeUnit;
    use crate::exec::{Dispatcher, Program};
    use crate::graph::{Graph, OpKind};

    /// Binary-tree routine over `k` config flags `f00..f{k-1}`: every
    /// leaf is its own choice whose efficiency mixes the leaf index, so
    /// the optimum sits at an interior point of the joint space.
    fn tree_routine(k: usize) -> Routine {
        let mut blocks = Vec::new();
        let mut choices = Vec::new();
        let mut provenance = BTreeMap::new();
        for i in 0..k {
            provenance.insert(format!("f{i:02}"), VarSource::ConfigFlag(format!("cfg.f{i:02}")));
        }
        // level-order complete binary tree: internal node j at depth d
        // tests flag d; leaves launch their path index
        let internal = (1 << k) - 1;
        for j in 0..internal {
            let d = (usize::BITS - 1 - (j + 1).leading_zeros()) as usize;
            blocks.push(Block {
                func: "joint_dispatch".into(),
                term: Term::CondBranch {
                    var: format!("f{d:02}"),
                    eq: "true".into(),
                    then_bb: 2 * j + 1,
                    else_bb: 2 * j + 2,
                },
            });
        }
        for leaf in 0..(1 << k) {
            let idx = choices.len();
            // deterministic irrational mix → optimum at an interior leaf
            let frac = ((leaf as f64) * 0.618_033_988_749_895).fract();
            choices.push(
                KernelChoice::new(&format!("leaf_{leaf}"), ComputeUnit::TensorCore)
                    .quality(0.4 + 0.6 * frac, 1.0, 1.0),
            );
            blocks.push(Block { func: "joint_dispatch".into(), term: Term::Launch { idx } });
        }
        Routine {
            api: "joint.tree".into(),
            frames: vec![],
            blocks,
            choices,
            provenance,
        }
    }

    fn tree_target(k: usize) -> (Program, Dispatcher) {
        let mut g = Graph::new("tree");
        let x = g.add(OpKind::Input, &[], "x");
        let w = g.add(OpKind::Weight, &[], "w");
        let m = g.add_attr1(OpKind::MatMul, &[x, w], "tree.proj", "dispatch", "joint.tree");
        g.add(OpKind::Output, &[m], "out");
        let mut p = Program::new(g);
        p.feed(0, Tensor::zeros(&[16, 32]));
        p.feed(1, Tensor::zeros(&[32, 16]));
        let mut d = Dispatcher::new();
        d.register("joint.tree", tree_routine(k));
        (p, d)
    }

    #[test]
    fn pruned_search_matches_exhaustive_optimum() {
        // soundness on routines up to 12 flags: the pruned search finds
        // the same optimum exhaustive enumeration does, while visiting
        // strictly fewer leaves
        for k in [4usize, 8, 10] {
            let (p, d) = tree_target(k);
            let env = Env::new();
            let dev = DeviceSpec::h200_sim();
            let cx = LintContext::new(&p, &d, &env, &dev).unwrap();
            let cfg = InteractConfig { max_joint_flags: 12 };
            let s = search_node(&cx, 2, &cfg).expect("searchable");
            assert_eq!(s.stats.exhaustive, 1 << k);
            assert!(
                s.stats.evaluated < s.stats.exhaustive,
                "k={k}: evaluated {} !< exhaustive {}",
                s.stats.evaluated,
                s.stats.exhaustive
            );
            assert!(s.stats.pruned > 0, "k={k}: nothing pruned");
            // exhaustive reference: price every joint outcome honestly
            let routine = tree_routine(k);
            let node = cx.node(2);
            let cur = &cx.cost[2];
            let mut best = f64::INFINITY;
            for o in routine.enumerate_outcomes() {
                let mut attrs = node.attrs.clone();
                for (k2, v) in &o.assignment {
                    attrs.insert(k2.clone(), v.clone());
                }
                let c = cx.op_cost(node.op, &attrs, &[vec![16, 32], vec![32, 16]], &[16, 16]);
                if c.time_us <= cur.time_us && c.energy_j < best {
                    best = c.energy_j;
                }
            }
            let hit = s.hit.expect("tree optimum beats the all-unset default");
            assert_eq!(
                (cur.energy_j - hit.saved_j).to_bits(),
                best.to_bits(),
                "k={k}: pruned optimum diverged from exhaustive"
            );
        }
    }

    #[test]
    fn single_flag_routine_yields_no_interaction() {
        // a lone tf32 branch is dtype-downcast's territory: the joint
        // search still finds the flip but joint_search filters < 2 flags
        let mut g = Graph::new("single");
        let x = g.add(OpKind::Input, &[], "x");
        let w = g.add(OpKind::Weight, &[], "w");
        g.add_attr1(OpKind::MatMul, &[x, w], "proj", "dispatch", "matmul");
        let mut p = Program::new(g);
        p.feed(0, Tensor::zeros(&[16, 32]));
        p.feed(1, Tensor::zeros(&[32, 16]));
        let mut d = Dispatcher::new();
        d.register("matmul", crate::systems::torch_matmul_routine());
        let env = Env::new();
        let dev = DeviceSpec::h200_sim();
        let cx = LintContext::new(&p, &d, &env, &dev).unwrap();
        let s = search_node(&cx, 2, &InteractConfig::default()).expect("searchable");
        let hit = s.hit.expect("tf32 flip saves");
        assert_eq!(hit.assignment.len(), 1, "{:?}", hit.assignment);
        let (diagnoses, _) = joint_search(&cx, &InteractConfig::default());
        assert!(diagnoses.is_empty(), "{diagnoses:?}");
    }

    #[test]
    fn flag_slicing_drops_cost_uniform_flags() {
        // a branch whose two launches price identically must not enter
        // the search space
        let mut provenance = BTreeMap::new();
        provenance.insert("dead".to_string(), VarSource::ConfigFlag("cfg.dead".into()));
        provenance.insert("live".to_string(), VarSource::ConfigFlag("cfg.live".into()));
        let r = Routine {
            api: "sliced".into(),
            frames: vec![],
            blocks: vec![
                Block {
                    func: "d".into(),
                    term: Term::CondBranch {
                        var: "dead".into(),
                        eq: "true".into(),
                        then_bb: 1,
                        else_bb: 2,
                    },
                },
                Block {
                    func: "d".into(),
                    term: Term::CondBranch {
                        var: "live".into(),
                        eq: "true".into(),
                        then_bb: 3,
                        else_bb: 4,
                    },
                },
                Block {
                    func: "d".into(),
                    term: Term::CondBranch {
                        var: "live".into(),
                        eq: "true".into(),
                        then_bb: 3,
                        else_bb: 4,
                    },
                },
                Block { func: "d".into(), term: Term::Launch { idx: 0 } },
                Block { func: "d".into(), term: Term::Launch { idx: 1 } },
            ],
            choices: vec![
                KernelChoice::new("fast", ComputeUnit::TensorCore),
                KernelChoice::new("slow", ComputeUnit::CudaCore),
            ],
            provenance,
        };
        let dev = DeviceSpec::h200_sim();
        let desc_costs: Vec<KernelCost> = r
            .choices
            .iter()
            .map(|c| {
                KernelDesc {
                    name: c.kernel.clone(),
                    unit: c.unit,
                    flops: 1e9,
                    bytes: 1e6,
                    efficiency: c.efficiency,
                    time_mult: c.time_mult,
                    fixed_time_us: 0.0,
                    fixed_power_w: 0.0,
                }
                .cost(&dev)
            })
            .collect();
        let flags = sliced_flags(&r, &desc_costs);
        let names: Vec<&str> = flags.iter().map(|(v, _)| v.as_str()).collect();
        // `dead` chooses between two identically-priced subtrees only
        // when `live` decides the kernel downstream — both its guarded
        // regions reach {fast, slow}, which *is* divergent, so `dead`
        // stays; `live` obviously stays. A flag is only dropped when
        // its guarded launches are cost-uniform:
        assert_eq!(names, vec!["dead", "live"]);
        let r2 = Routine::branch_on(
            "uniform",
            vec![],
            "d",
            "flip",
            "true",
            VarSource::ConfigFlag("cfg.flip".into()),
            KernelChoice::new("a", ComputeUnit::TensorCore),
            KernelChoice::new("a2", ComputeUnit::TensorCore),
        );
        let costs2: Vec<KernelCost> = vec![desc_costs[0]; 2];
        assert!(sliced_flags(&r2, &costs2).is_empty(), "cost-uniform flag must be sliced out");
    }

    #[test]
    fn interact_name_is_stable() {
        assert_eq!(interact_name("case-c8-joint"), "interact~case-c8-joint");
    }

    #[test]
    fn max_joint_flags_caps_the_space() {
        let (p, d) = tree_target(8);
        let env = Env::new();
        let dev = DeviceSpec::h200_sim();
        let cx = LintContext::new(&p, &d, &env, &dev).unwrap();
        let s = search_node(&cx, 2, &InteractConfig { max_joint_flags: 3 }).expect("searchable");
        assert_eq!(s.stats.exhaustive, 8, "2^3 leaves with 5 flags pinned");
    }
}
