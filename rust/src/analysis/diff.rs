//! Fully static differential audit between two system targets.
//!
//! The dynamic pipeline proves waste by *running* two systems and
//! diffing measured joules; this module is its measure-free analogue.
//! Both targets are analysed with the same [`LintContext`] the lint
//! rules use, their billed (non-virtual) nodes are matched region-by-
//! region, and the per-region static [`KernelCost`](crate::energy::KernelCost)
//! bills are diffed into a ranked [`StaticDiffReport`] — per-region ΔJ,
//! WASTEFUL/cheaper verdicts, and unmatched-region attribution — before
//! a single joule is spent.
//!
//! Matching is tiered so one structural divergence cannot poison every
//! downstream region (subtree hashes cascade):
//!
//! 1. **Hash** — cross-graph structural subtree hashes collide; the
//!    regions compute the same function of the same-shaped sources.
//! 2. **Label** — same op under the same system-stripped label suffix
//!    (`torch.conv2d` ↔ `tf.conv2d` both own `conv2d`).
//! 3. **Bucket** — [`matching::CandidateIndex`](crate::matching)-style
//!    coarse buckets on (op, element count): last-resort pairing for
//!    renamed regions of identical geometry.
//! 4. **Fuzzy** — bounded edit-distance over system-stripped call-site
//!    labels, same op only, unique mutual best: recovers renamed sites
//!    (`attn.q_proj` ↔ `attn.query_proj`) whose geometry also drifted
//!    past the bucket tier. Ambiguous candidates (ties) stay unmatched
//!    rather than guessing.
//!
//! Whatever survives all four tiers is reported as an unmatched
//! region: energy one implementation spends that the other simply does
//! not have — the concat/split skip handling only one UNet build
//! performs, the layout staging copies only one conv stack needs.

use std::collections::{BTreeMap, BTreeSet};

use crate::energy::DeviceSpec;
use crate::fingerprint::{mix64, op_signature};
use crate::graph::NodeId;
use crate::util::pool::par_map;

use super::suite::{LintTarget, TargetReport};
use super::{sort_findings, LintContext, LintFinding, Severity};

// ---------------------------------------------------------------------
// Cross-graph hashes
// ---------------------------------------------------------------------

/// Structural subtree hash comparable *across* graphs. Differs from
/// [`super::structural_hashes`] in exactly the two places that are
/// graph-private identity: leaves hash their op + inferred shape
/// instead of their node id/label (two systems feed the same activation
/// under different names), and the `dispatch` attribute is ignored
/// (it names a system-specific routine for the same mathematical op).
pub fn cross_graph_hashes(cx: &LintContext) -> Vec<u64> {
    let g = cx.graph;
    let mut hashes = vec![0u64; g.len()];
    for node in &g.nodes {
        let mut h = mix64(op_signature("", node.op.name()));
        for (k, v) in &node.attrs {
            if k == "dispatch" {
                continue;
            }
            h = mix64(h ^ op_signature(k, v));
        }
        if node.inputs.is_empty() {
            h = mix64(h ^ op_signature(&shape_sig(cx.shapes[node.id].as_deref()), "leaf"));
        }
        for &i in &node.inputs {
            h = mix64(h.rotate_left(7) ^ hashes[i]);
        }
        hashes[node.id] = h;
    }
    hashes
}

fn shape_sig(shape: Option<&[usize]>) -> String {
    match shape {
        Some(s) => s.iter().map(|d| d.to_string()).collect::<Vec<_>>().join("x"),
        None => "?".to_string(),
    }
}

/// Strip the leading system prefix from a label (`torch.conv2d` →
/// `conv2d`); labels without a dot are their own suffix.
fn label_suffix(label: &str) -> &str {
    match label.split_once('.') {
        Some((_, rest)) => rest,
        None => label,
    }
}

fn numel(cx: &LintContext, id: NodeId) -> usize {
    cx.shapes[id].as_ref().map(|s| s.iter().product()).unwrap_or(0)
}

/// Kernel the target's dispatcher selects for a node under its env —
/// the name that explains *why* the two sides bill differently.
fn kernel_for(cx: &LintContext, id: NodeId) -> String {
    let node = cx.node(id);
    let key =
        node.attrs.get("dispatch").cloned().unwrap_or_else(|| node.op.name().to_string());
    let env = cx.env.merged(&node.attrs);
    cx.dispatcher.dispatch(node.op, &key, &env).choice.kernel
}

// ---------------------------------------------------------------------
// Report types
// ---------------------------------------------------------------------

/// Which matching tier paired a region.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum MatchTier {
    Hash,
    Label,
    Bucket,
    Fuzzy,
}

impl MatchTier {
    pub fn name(&self) -> &'static str {
        match self {
            MatchTier::Hash => "hash",
            MatchTier::Label => "label",
            MatchTier::Bucket => "bucket",
            MatchTier::Fuzzy => "fuzzy",
        }
    }
}

/// Verdict on one matched region pair.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RegionVerdict {
    /// Target A bills significantly more than B for the same region.
    AWasteful,
    /// Target B bills significantly more than A.
    BWasteful,
    /// Within threshold: the implementations price the region alike.
    Close,
}

impl RegionVerdict {
    pub fn name(&self) -> &'static str {
        match self {
            RegionVerdict::AWasteful => "A WASTEFUL",
            RegionVerdict::BWasteful => "B WASTEFUL",
            RegionVerdict::Close => "close",
        }
    }
}

/// One matched region pair with its static energy delta.
#[derive(Clone, Debug)]
pub struct RegionDelta {
    pub node_a: NodeId,
    pub node_b: NodeId,
    pub label_a: String,
    pub label_b: String,
    pub op: &'static str,
    pub kernel_a: String,
    pub kernel_b: String,
    pub a_j: f64,
    pub b_j: f64,
    /// `b_j - a_j`: positive means B burns more.
    pub delta_j: f64,
    pub tier: MatchTier,
    pub verdict: RegionVerdict,
}

/// A billed region with no counterpart on the other side.
#[derive(Clone, Debug)]
pub struct UnmatchedRegion {
    pub node: NodeId,
    pub label: String,
    pub op: &'static str,
    pub cost_j: f64,
}

/// Thresholds deciding when a matched delta is worth reporting.
#[derive(Clone, Copy, Debug)]
pub struct StaticDiffConfig {
    /// Relative gap (fraction of the larger side) below which a
    /// matched pair is `close`.
    pub rel_threshold: f64,
    /// Absolute joule floor below which deltas and unmatched regions
    /// are noise.
    pub abs_floor_j: f64,
}

impl Default for StaticDiffConfig {
    fn default() -> StaticDiffConfig {
        StaticDiffConfig { rel_threshold: 0.05, abs_floor_j: 1e-6 }
    }
}

/// The static analogue of a measured differential audit: every billed
/// region of A paired (or not) with a region of B, ranked by |ΔJ|.
#[derive(Clone, Debug)]
pub struct StaticDiffReport {
    pub target_a: String,
    pub target_b: String,
    pub nodes_a: usize,
    pub nodes_b: usize,
    pub total_a_j: f64,
    pub total_b_j: f64,
    /// Matched region pairs, largest |ΔJ| first.
    pub regions: Vec<RegionDelta>,
    /// Billed regions of A with no counterpart in B, ascending id.
    pub unmatched_a: Vec<UnmatchedRegion>,
    /// Billed regions of B with no counterpart in A, ascending id.
    pub unmatched_b: Vec<UnmatchedRegion>,
    /// Set when a side failed validation/analysis; content is empty.
    pub error: Option<String>,
}

/// Pseudo-target name a pair diff reports under (manifest/`--target`).
pub fn diff_name(a: &str, b: &str) -> String {
    format!("diff~{a}~{b}")
}

impl StaticDiffReport {
    /// Wasteful verdicts and significant unmatched regions as ordinary
    /// lint findings, so the manifest gate and renderers apply
    /// unchanged. Cross-graph node ids are ambiguous in a pseudo-target
    /// so `nodes` stays empty; the ids are spelled in the suggestion.
    pub fn findings(&self, cfg: &StaticDiffConfig) -> Vec<LintFinding> {
        let mut out = Vec::new();
        for r in &self.regions {
            if r.verdict == RegionVerdict::Close {
                continue;
            }
            let (loser, winner, cheap_j) = match r.verdict {
                RegionVerdict::AWasteful => (&self.target_a, &self.target_b, r.b_j),
                _ => (&self.target_b, &self.target_a, r.a_j),
            };
            let pct = if cheap_j > 0.0 { r.delta_j.abs() / cheap_j * 100.0 } else { 0.0 };
            out.push(LintFinding {
                rule: "static-diff",
                severity: Severity::Warn,
                nodes: vec![],
                label: format!("{} <-> {}", r.label_a, r.label_b),
                est_wasted_j: r.delta_j.abs(),
                suggestion: format!(
                    "{op} region `{la}` (node {na}, {ka}) vs `{lb}` (node {nb}, {kb}), \
                     matched by {tier}: {loser} bills {pct:.0}% more than {winner} for \
                     the same region ({aj:.3e} J vs {bj:.3e} J)",
                    op = r.op,
                    la = r.label_a,
                    na = r.node_a,
                    ka = r.kernel_a,
                    lb = r.label_b,
                    nb = r.node_b,
                    kb = r.kernel_b,
                    tier = r.tier.name(),
                    loser = loser,
                    winner = winner,
                    pct = pct,
                    aj = r.a_j,
                    bj = r.b_j,
                ),
                steps: vec![],
            });
        }
        let unmatched = [
            (&self.unmatched_a, &self.target_a, &self.target_b),
            (&self.unmatched_b, &self.target_b, &self.target_a),
        ];
        for (regions, owner, other) in unmatched {
            for u in regions.iter().filter(|u| u.cost_j > cfg.abs_floor_j) {
                out.push(LintFinding {
                    rule: "static-diff-unmatched",
                    severity: Severity::Info,
                    nodes: vec![],
                    label: format!("{owner}:{}", u.label),
                    est_wasted_j: u.cost_j,
                    suggestion: format!(
                        "{op} region `{label}` (node {node}) on {owner} has no \
                         structural counterpart on {other}: {cost:.3e} J of \
                         implementation divergence",
                        op = u.op,
                        label = u.label,
                        node = u.node,
                        owner = owner,
                        other = other,
                        cost = u.cost_j,
                    ),
                    steps: vec![],
                });
            }
        }
        sort_findings(&mut out);
        out
    }

    /// Repackage as a [`TargetReport`] under the `diff~a~b` pseudo-
    /// target, so `lint --expect` gates static diffs with the same
    /// manifest machinery as single-target findings.
    pub fn to_target_report(&self, cfg: &StaticDiffConfig) -> TargetReport {
        TargetReport {
            name: diff_name(&self.target_a, &self.target_b),
            nodes: self.nodes_a + self.nodes_b,
            static_j: self.total_a_j + self.total_b_j,
            findings: self.findings(cfg),
            error: self.error.clone(),
            interactions: vec![],
        }
    }
}

// ---------------------------------------------------------------------
// Matching
// ---------------------------------------------------------------------

/// Pair remaining candidates whose keys collide, zipping each bucket in
/// ascending node-id order (deterministic; surplus stays unmatched for
/// the next tier). `BTreeMap` keeps bucket iteration ordered.
fn pair_by_key<K: Ord>(
    rem_a: &mut Vec<NodeId>,
    rem_b: &mut Vec<NodeId>,
    matched: &mut Vec<(NodeId, NodeId, MatchTier)>,
    tier: MatchTier,
    key_a: impl Fn(NodeId) -> K,
    key_b: impl Fn(NodeId) -> K,
) {
    let mut buckets: BTreeMap<K, (Vec<NodeId>, Vec<NodeId>)> = BTreeMap::new();
    for &id in rem_a.iter() {
        buckets.entry(key_a(id)).or_default().0.push(id);
    }
    for &id in rem_b.iter() {
        buckets.entry(key_b(id)).or_default().1.push(id);
    }
    let mut used_a = BTreeSet::new();
    let mut used_b = BTreeSet::new();
    for (_, (va, vb)) in buckets {
        for (&x, &y) in va.iter().zip(vb.iter()) {
            matched.push((x, y, tier));
            used_a.insert(x);
            used_b.insert(y);
        }
    }
    rem_a.retain(|id| !used_a.contains(id));
    rem_b.retain(|id| !used_b.contains(id));
}

/// Levenshtein distance (chars), the classic two-row DP.
fn edit_distance(a: &str, b: &str) -> usize {
    let a: Vec<char> = a.chars().collect();
    let b: Vec<char> = b.chars().collect();
    let mut prev: Vec<usize> = (0..=b.len()).collect();
    for (i, &ca) in a.iter().enumerate() {
        let mut cur = Vec::with_capacity(b.len() + 1);
        cur.push(i + 1);
        for (j, &cb) in b.iter().enumerate() {
            let sub = prev[j] + usize::from(ca != cb);
            cur.push(sub.min(prev[j + 1] + 1).min(cur[j] + 1));
        }
        prev = cur;
    }
    prev[b.len()]
}

/// A fuzzy candidate is admissible when the labels differ by at most a
/// third of the longer suffix — tight enough that `q_proj` ↔
/// `query_proj` recovers while structurally unrelated short labels
/// (whose bound rounds down to ≤ 1 edit) cannot drift together.
fn fuzzy_bound(a: &str, b: &str) -> usize {
    a.chars().count().max(b.chars().count()) / 3
}

/// For each node in `from`, its unique nearest admissible same-op
/// candidate in `to` by label-suffix edit distance. Nodes whose best
/// distance is tied between two candidates get no entry: a fuzzy match
/// must be unambiguous or it is no match at all.
fn fuzzy_best(
    from: &[NodeId],
    to: &[NodeId],
    cx_f: &LintContext,
    cx_t: &LintContext,
) -> BTreeMap<NodeId, NodeId> {
    let mut out = BTreeMap::new();
    for &x in from {
        let sx = label_suffix(&cx_f.node(x).label);
        let mut best: Option<(usize, NodeId)> = None;
        let mut tied = false;
        for &y in to {
            // distinct ops never fuzzy-match, whatever their labels
            if cx_f.node(x).op.name() != cx_t.node(y).op.name() {
                continue;
            }
            let sy = label_suffix(&cx_t.node(y).label);
            let d = edit_distance(sx, sy);
            if d > fuzzy_bound(sx, sy) {
                continue;
            }
            match best {
                Some((bd, _)) if d > bd => {}
                Some((bd, _)) if d == bd => tied = true,
                _ => {
                    best = Some((d, y));
                    tied = false;
                }
            }
        }
        if let (Some((_, y)), false) = (best, tied) {
            out.insert(x, y);
        }
    }
    out
}

/// Fourth tier: pair remaining regions whose label suffixes are each
/// other's unique nearest admissible edit-distance neighbour (same op
/// required on both ends; ties stay unmatched).
fn pair_fuzzy(
    rem_a: &mut Vec<NodeId>,
    rem_b: &mut Vec<NodeId>,
    matched: &mut Vec<(NodeId, NodeId, MatchTier)>,
    cxa: &LintContext,
    cxb: &LintContext,
) {
    let fwd = fuzzy_best(rem_a, rem_b, cxa, cxb);
    let back = fuzzy_best(rem_b, rem_a, cxb, cxa);
    let mut used_a = BTreeSet::new();
    let mut used_b = BTreeSet::new();
    for (&x, &y) in &fwd {
        if back.get(&y) == Some(&x) {
            matched.push((x, y, MatchTier::Fuzzy));
            used_a.insert(x);
            used_b.insert(y);
        }
    }
    rem_a.retain(|id| !used_a.contains(id));
    rem_b.retain(|id| !used_b.contains(id));
}

/// Diff two analysed targets. Pure function of the two contexts; the
/// caller owns naming.
pub fn diff_contexts(
    name_a: &str,
    cxa: &LintContext,
    name_b: &str,
    cxb: &LintContext,
    cfg: &StaticDiffConfig,
) -> StaticDiffReport {
    let ha = cross_graph_hashes(cxa);
    let hb = cross_graph_hashes(cxb);
    let billed = |cx: &LintContext| -> Vec<NodeId> {
        cx.graph.nodes.iter().filter(|n| !n.op.is_virtual()).map(|n| n.id).collect()
    };
    let mut rem_a = billed(cxa);
    let mut rem_b = billed(cxb);
    let mut matched: Vec<(NodeId, NodeId, MatchTier)> = Vec::new();
    pair_by_key(&mut rem_a, &mut rem_b, &mut matched, MatchTier::Hash, |id| ha[id], |id| hb[id]);
    let label_key = |cx: &LintContext, id: NodeId| -> (String, String) {
        let n = cx.node(id);
        (n.op.name().to_string(), label_suffix(&n.label).to_string())
    };
    pair_by_key(
        &mut rem_a,
        &mut rem_b,
        &mut matched,
        MatchTier::Label,
        |id| label_key(cxa, id),
        |id| label_key(cxb, id),
    );
    pair_by_key(
        &mut rem_a,
        &mut rem_b,
        &mut matched,
        MatchTier::Bucket,
        |id| (cxa.node(id).op.name(), numel(cxa, id)),
        |id| (cxb.node(id).op.name(), numel(cxb, id)),
    );
    pair_fuzzy(&mut rem_a, &mut rem_b, &mut matched, cxa, cxb);
    matched.sort_unstable_by_key(|&(a, b, _)| (a, b));
    let mut regions: Vec<RegionDelta> = matched
        .into_iter()
        .map(|(a, b, tier)| {
            let (a_j, b_j) = (cxa.cost_j(a), cxb.cost_j(b));
            let delta_j = b_j - a_j;
            let gap = delta_j.abs();
            let verdict = if gap > cfg.abs_floor_j && gap >= cfg.rel_threshold * a_j.max(b_j) {
                if delta_j > 0.0 {
                    RegionVerdict::BWasteful
                } else {
                    RegionVerdict::AWasteful
                }
            } else {
                RegionVerdict::Close
            };
            RegionDelta {
                node_a: a,
                node_b: b,
                label_a: cxa.node(a).label.clone(),
                label_b: cxb.node(b).label.clone(),
                op: cxa.node(a).op.name(),
                kernel_a: kernel_for(cxa, a),
                kernel_b: kernel_for(cxb, b),
                a_j,
                b_j,
                delta_j,
                tier,
                verdict,
            }
        })
        .collect();
    regions.sort_by(|x, y| {
        y.delta_j
            .abs()
            .total_cmp(&x.delta_j.abs())
            .then(x.label_a.cmp(&y.label_a))
            .then(x.node_a.cmp(&y.node_a))
    });
    let unmatched = |cx: &LintContext, rem: &[NodeId]| -> Vec<UnmatchedRegion> {
        rem.iter()
            .map(|&id| UnmatchedRegion {
                node: id,
                label: cx.node(id).label.clone(),
                op: cx.node(id).op.name(),
                cost_j: cx.cost_j(id),
            })
            .collect()
    };
    StaticDiffReport {
        target_a: name_a.to_string(),
        target_b: name_b.to_string(),
        nodes_a: cxa.graph.len(),
        nodes_b: cxb.graph.len(),
        total_a_j: cxa.total_static_j(),
        total_b_j: cxb.total_static_j(),
        regions,
        unmatched_a: unmatched(cxa, &rem_a),
        unmatched_b: unmatched(cxb, &rem_b),
        error: None,
    }
}

/// Diff two suite targets (analysing each under its own dispatcher/env
/// on the shared device). Fails typed when either graph is malformed.
pub fn diff_targets(
    a: &LintTarget,
    b: &LintTarget,
    device: &DeviceSpec,
    cfg: &StaticDiffConfig,
) -> crate::Result<StaticDiffReport> {
    let cxa = LintContext::new(&a.run.prog, &a.run.dispatcher, &a.run.env, device)
        .map_err(|e| e.context(format!("static diff target `{}`", a.name)))?;
    let cxb = LintContext::new(&b.run.prog, &b.run.dispatcher, &b.run.env, device)
        .map_err(|e| e.context(format!("static diff target `{}`", b.name)))?;
    Ok(diff_contexts(&a.name, &cxa, &b.name, &cxb, cfg))
}

/// All comparable suite pairs: targets sharing a declared workload
/// family, in (i, j) order with i < j.
pub fn family_pairs(targets: &[LintTarget]) -> Vec<(usize, usize)> {
    let mut out = Vec::new();
    for i in 0..targets.len() {
        for j in (i + 1)..targets.len() {
            if let (Some(fa), Some(fb)) = (targets[i].family, targets[j].family) {
                if fa == fb {
                    out.push((i, j));
                }
            }
        }
    }
    out
}

/// Run the static diff over every same-family pair, fanning out across
/// `threads` workers. Pair order and per-pair content are fully
/// deterministic, so the result is bit-identical for any worker count.
pub fn diff_suite(
    targets: &[LintTarget],
    device: &DeviceSpec,
    threads: usize,
    cfg: &StaticDiffConfig,
) -> Vec<StaticDiffReport> {
    let pairs = family_pairs(targets);
    par_map(&pairs, threads, |&(i, j)| {
        let (a, b) = (&targets[i], &targets[j]);
        diff_targets(a, b, device, cfg).unwrap_or_else(|e| StaticDiffReport {
            target_a: a.name.clone(),
            target_b: b.name.clone(),
            nodes_a: a.run.prog.graph.len(),
            nodes_b: b.run.prog.graph.len(),
            total_a_j: 0.0,
            total_b_j: 0.0,
            regions: vec![],
            unmatched_a: vec![],
            unmatched_b: vec![],
            error: Some(e.to_string()),
        })
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dispatch::Env;
    use crate::exec::{Dispatcher, Program};
    use crate::graph::{Graph, OpKind};
    use crate::tensor::Tensor;

    fn ctx_parts() -> (Dispatcher, Env, DeviceSpec) {
        (Dispatcher::new(), Env::new(), DeviceSpec::h200_sim())
    }

    fn mlp(sys: &str, extra_copy: bool) -> Program {
        let mut g = Graph::new(sys);
        let x = g.add(OpKind::Input, &[], "x");
        let w = g.add(OpKind::Weight, &[], "w");
        let m = g.add(OpKind::MatMul, &[x, w], &format!("{sys}.proj"));
        let act = g.add(OpKind::Gelu, &[m], &format!("{sys}.act"));
        let tip = if extra_copy {
            g.add(OpKind::Copy, &[act], &format!("{sys}.staging_copy"))
        } else {
            act
        };
        g.add(OpKind::Output, &[tip], "out");
        let mut p = Program::new(g);
        p.feed(0, Tensor::zeros(&[16, 32]));
        p.feed(1, Tensor::zeros(&[32, 8]));
        p
    }

    #[test]
    fn identical_programs_diff_empty() {
        let (d, e, dev) = ctx_parts();
        let p = mlp("a", false);
        let q = mlp("a", false);
        let cxa = LintContext::new(&p, &d, &e, &dev).unwrap();
        let cxb = LintContext::new(&q, &d, &e, &dev).unwrap();
        let rep = diff_contexts("a", &cxa, "b", &cxb, &StaticDiffConfig::default());
        assert!(rep.unmatched_a.is_empty() && rep.unmatched_b.is_empty());
        assert!(rep.regions.iter().all(|r| r.tier == MatchTier::Hash));
        assert!(rep.regions.iter().all(|r| r.verdict == RegionVerdict::Close));
        assert!(rep.findings(&StaticDiffConfig::default()).is_empty());
    }

    #[test]
    fn renamed_same_structure_matches_by_hash() {
        let (d, e, dev) = ctx_parts();
        let p = mlp("torch", false);
        let q = mlp("tf", false);
        let cxa = LintContext::new(&p, &d, &e, &dev).unwrap();
        let cxb = LintContext::new(&q, &d, &e, &dev).unwrap();
        let rep = diff_contexts("torch", &cxa, "tf", &cxb, &StaticDiffConfig::default());
        // labels differ in their system prefix but structure is equal:
        // every billed region pairs at the hash tier with zero delta
        assert_eq!(rep.regions.len(), 2);
        assert!(rep.regions.iter().all(|r| r.tier == MatchTier::Hash && r.delta_j == 0.0));
    }

    #[test]
    fn extra_region_is_attributed_unmatched() {
        let (d, e, dev) = ctx_parts();
        let p = mlp("a", false);
        let q = mlp("b", true);
        let cxa = LintContext::new(&p, &d, &e, &dev).unwrap();
        let cxb = LintContext::new(&q, &d, &e, &dev).unwrap();
        let rep = diff_contexts("a", &cxa, "b", &cxb, &StaticDiffConfig::default());
        assert!(rep.unmatched_a.is_empty());
        assert_eq!(rep.unmatched_b.len(), 1);
        assert_eq!(rep.unmatched_b[0].label, "b.staging_copy");
        let f = rep.findings(&StaticDiffConfig::default());
        assert!(
            f.iter().any(|f| f.rule == "static-diff-unmatched"
                && f.label == "b:b.staging_copy"
                && f.est_wasted_j > 0.0),
            "findings: {f:?}"
        );
    }

    #[test]
    fn label_tier_pairs_when_attrs_differ() {
        let (d, e, dev) = ctx_parts();
        let build = |sys: &str, pad: &str| {
            let mut g = Graph::new(sys);
            let x = g.add(OpKind::Input, &[], "x");
            let w = g.add(OpKind::Weight, &[], "w");
            g.add_attr1(OpKind::Conv2d, &[x, w], &format!("{sys}.conv2d"), "pad", pad);
            let mut p = Program::new(g);
            p.feed(0, Tensor::zeros(&[2, 8, 16, 16]));
            p.feed(1, Tensor::zeros(&[8, 8, 3, 3]));
            p
        };
        let p = build("torch", "1");
        let q = build("tf", "0");
        let cxa = LintContext::new(&p, &d, &e, &dev).unwrap();
        let cxb = LintContext::new(&q, &d, &e, &dev).unwrap();
        let rep = diff_contexts("torch", &cxa, "tf", &cxb, &StaticDiffConfig::default());
        // differing pad attr breaks the hash tier; the shared label
        // suffix `conv2d` still pairs the regions
        assert_eq!(rep.regions.len(), 1);
        assert_eq!(rep.regions[0].tier, MatchTier::Label);
        assert!(rep.unmatched_a.is_empty() && rep.unmatched_b.is_empty());
    }

    #[test]
    fn diff_name_is_stable() {
        assert_eq!(diff_name("x", "y"), "diff~x~y");
    }

    fn attn(sys: &str, proj_label: &str, width: usize, act: OpKind) -> Program {
        let mut g = Graph::new(sys);
        let x = g.add(OpKind::Input, &[], "x");
        let w = g.add(OpKind::Weight, &[], "w");
        let m = g.add(OpKind::MatMul, &[x, w], &format!("{sys}.{proj_label}"));
        let a = g.add(act, &[m], &format!("{sys}.attn.act"));
        g.add(OpKind::Output, &[a], "out");
        let mut p = Program::new(g);
        p.feed(0, Tensor::zeros(&[16, 32]));
        p.feed(1, Tensor::zeros(&[32, width]));
        p
    }

    #[test]
    fn fuzzy_tier_recovers_renamed_region_but_never_across_ops() {
        let (d, e, dev) = ctx_parts();
        // projection widened 128 → 96, so hash (leaf shapes), label
        // (suffix), and bucket (numel) all fail; only the bounded edit
        // distance can still pair the renamed site
        let p = attn("a", "attn.q_proj", 128, OpKind::Gelu);
        let q = attn("b", "attn.query_proj", 96, OpKind::Relu);
        let cxa = LintContext::new(&p, &d, &e, &dev).unwrap();
        let cxb = LintContext::new(&q, &d, &e, &dev).unwrap();
        let rep = diff_contexts("a", &cxa, "b", &cxb, &StaticDiffConfig::default());
        assert_eq!(rep.regions.len(), 1, "regions: {:?}", rep.regions);
        assert_eq!(rep.regions[0].tier, MatchTier::Fuzzy);
        assert_eq!(rep.regions[0].label_a, "a.attn.q_proj");
        assert_eq!(rep.regions[0].label_b, "b.attn.query_proj");
        // negative control: the activations share the exact suffix
        // `attn.act` (edit distance 0) but differ in op — distinct ops
        // must never fuzzy-match, so both stay unmatched
        assert_eq!(rep.unmatched_a.len(), 1);
        assert_eq!(rep.unmatched_b.len(), 1);
        assert_eq!(rep.unmatched_a[0].label, "a.attn.act");
        assert_eq!(rep.unmatched_b[0].label, "b.attn.act");
    }

    #[test]
    fn fuzzy_ties_stay_unmatched() {
        let (d, e, dev) = ctx_parts();
        let p = attn("a", "attn.q_proj", 128, OpKind::Gelu);
        // two equidistant candidates for `q_proj`: the tie must leave
        // all three projections unmatched rather than guess
        let mut g = Graph::new("b");
        let x = g.add(OpKind::Input, &[], "x");
        let w1 = g.add(OpKind::Weight, &[], "w1");
        let w2 = g.add(OpKind::Weight, &[], "w2");
        let m1 = g.add(OpKind::MatMul, &[x, w1], "b.attn.qk_proj");
        let m2 = g.add(OpKind::MatMul, &[x, w2], "b.attn.qv_proj");
        let s = g.add(OpKind::Add, &[m1, m2], "b.attn.act");
        g.add(OpKind::Output, &[s], "out");
        let mut q = Program::new(g);
        q.feed(0, Tensor::zeros(&[16, 32]));
        q.feed(1, Tensor::zeros(&[32, 96]));
        q.feed(2, Tensor::zeros(&[32, 96]));
        let cxa = LintContext::new(&p, &d, &e, &dev).unwrap();
        let cxb = LintContext::new(&q, &d, &e, &dev).unwrap();
        let rep = diff_contexts("a", &cxa, "b", &cxb, &StaticDiffConfig::default());
        assert!(
            rep.regions.iter().all(|r| r.tier != MatchTier::Fuzzy),
            "tied fuzzy candidates must stay unmatched: {:?}",
            rep.regions
        );
        assert!(rep.unmatched_a.iter().any(|u| u.label == "a.attn.q_proj"));
    }

    #[test]
    fn edit_distance_is_the_levenshtein_metric() {
        assert_eq!(edit_distance("", ""), 0);
        assert_eq!(edit_distance("q_proj", "q_proj"), 0);
        assert_eq!(edit_distance("q_proj", "query_proj"), 4);
        assert_eq!(edit_distance("kitten", "sitting"), 3);
        assert_eq!(edit_distance("abc", ""), 3);
    }
}
