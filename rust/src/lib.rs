//! # Magneton — differential energy debugging for ML systems
//!
//! Reproduction of *"Magneton: Optimizing Energy Efficiency of ML Systems
//! via Differential Energy Debugging"*. Given two ML systems executing the
//! same workload, Magneton profiles energy at the operator granularity,
//! matches semantically equivalent subgraphs across their computational
//! graphs (SVD-invariant tensor fingerprints + dominator-path recursive
//! matching), detects subgraph pairs whose energy diverges with no
//! performance/accuracy trade-off, and diagnoses the root cause by diffing
//! the call paths and basic-block traces that lead to GPU kernel selection.
//!
//! The crate is organised bottom-up:
//!
//! * substrates — [`util`], [`prop`], [`tensor`], [`linalg`], [`graph`]
//! * simulation — [`energy`], [`trace`], [`dispatch`], [`exec`]
//! * Magneton core — [`fingerprint`], [`matching`], [`detect`], [`diagnose`]
//! * evaluation fleet — [`systems`], [`workload`], [`cases`], [`profiler`]
//! * integration — [`runtime`] (PJRT/XLA), [`coordinator`], [`stream`],
//!   [`telemetry`], [`report`]
//!
//! Two consumption modes sit on top of the core:
//!
//! * **batch** ([`coordinator`]) — audit two finished runs and diagnose
//!   each finding; scaled across N system pairs by
//!   [`coordinator::fleet::FleetAudit`];
//! * **streaming** ([`stream`]) — audit live serving traffic in bounded
//!   memory, with resynchronisation across dropped kernels, content
//!   guards, and fleet-wide divergence correlation
//!   ([`coordinator::fleet::StreamFleet`]); [`telemetry`] persists the
//!   rolling state as replayable snapshots (`magneton replay`).
//!
//! See `README.md` for a subcommand-by-subcommand quickstart and
//! `DESIGN.md` (repository root) for the module map, per-experiment
//! index, and the substitution table (simulated GPU in place of H200 +
//! physical power meter, mini ML systems in place of vLLM/SGLang/...,
//! etc.).
//!
//! # Example: a minimal differential audit
//!
//! ```
//! use magneton::coordinator::{Magneton, SysRun};
//! use magneton::dispatch::{Env, KernelChoice, Routine};
//! use magneton::energy::{ComputeUnit, DeviceSpec};
//! use magneton::exec::{Dispatcher, Program};
//! use magneton::graph::{Graph, OpKind};
//! use magneton::tensor::Tensor;
//! use magneton::util::Prng;
//!
//! // Two systems computing the same projection; side A's matmul kernel
//! // burns extra energy at equal speed (quality 0.6).
//! fn system(label: &str, kernel_quality: f64) -> SysRun {
//!     let mut rng = Prng::new(40); // same seed: same workload tensors
//!     let mut g = Graph::new(label);
//!     let x = g.add(OpKind::Input, &[], "x");
//!     let w = g.add(OpKind::Weight, &[], "w");
//!     let m = g.add(OpKind::MatMul, &[x, w], "proj");
//!     g.add(OpKind::Output, &[m], "out");
//!     let mut prog = Program::new(g);
//!     prog.feed(0, Tensor::randn(&mut rng, &[128, 256]));
//!     prog.feed(1, Tensor::randn(&mut rng, &[256, 256]));
//!     let mut disp = Dispatcher::new();
//!     disp.register(
//!         "matmul",
//!         Routine::direct(
//!             "torch.matmul",
//!             vec![],
//!             KernelChoice::new("gemm", ComputeUnit::TensorCore)
//!                 .quality(kernel_quality, 1.0, 1.0),
//!         ),
//!     );
//!     SysRun::new(label, disp, Env::new(), prog)
//! }
//!
//! let mag = Magneton::new(DeviceSpec::h200_sim());
//! let outcome = mag.audit(&system("wasteful", 0.6), &system("optimal", 1.0));
//! assert!(outcome.detected(), "the 0.6-quality kernel must be flagged");
//! ```

pub mod util;
pub mod prop;
pub mod tensor;
pub mod linalg;
pub mod graph;
pub mod energy;
pub mod trace;
pub mod dispatch;
pub mod exec;
pub mod fingerprint;
pub mod matching;
pub mod detect;
pub mod diagnose;
pub mod profiler;
pub mod systems;
pub mod workload;
pub mod cases;
pub mod runtime;
pub mod coordinator;
pub mod stream;
pub mod telemetry;
pub mod dash;
pub mod analysis;
pub mod report;

/// Crate-wide error type (the offline registry has no `anyhow`): a plain
/// message, optionally chained with context lines by [`Error::context`].
#[derive(Debug)]
pub struct Error(pub String);

impl Error {
    pub fn msg(m: impl Into<String>) -> Error {
        Error(m.into())
    }

    /// Prepend a context line, `anyhow::Context`-style.
    pub fn context(self, ctx: impl std::fmt::Display) -> Error {
        Error(format!("{ctx}: {}", self.0))
    }
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Error {
        Error(e.to_string())
    }
}

/// Crate-wide result type.
pub type Result<T> = std::result::Result<T, Error>;
