//! The 8 previously-unknown issues Magneton exposed (paper Table 3),
//! reconstructed as differential scenarios. In the paper these were
//! found by cross-system comparison and operator fuzzing; here the same
//! comparisons are wired as scenarios and the fuzzing harness in
//! `examples/conv_layout_hunt.rs` re-discovers the layout trade-off.

use crate::coordinator::SysRun;
use crate::diagnose::Category;
use crate::dispatch::Env;
use crate::systems::frameworks as fw;
use crate::systems::llm;
use crate::systems::SystemId;
use crate::util::Prng;

use super::Scenario;

/// pytorch-157334 (M) — Conv2D inefficient under NCHW layout.
fn conv_nchw(rng: &mut Prng) -> (SysRun, SysRun) {
    let spec = fw::ConvSpec::fig5c();
    let (x, w) = fw::conv_params(rng, spec);
    let a = SysRun::new(
        "pytorch(nchw)",
        fw::torch_dispatcher(),
        Env::new(),
        fw::build_conv("torch", spec, fw::ConvLayout::Nchw, &x, &w, "torch.conv2d"),
    );
    let b = SysRun::new(
        "pytorch(channels-last)",
        fw::torch_dispatcher(),
        Env::new(),
        fw::build_conv("torch", spec, fw::ConvLayout::Nhwc, &x, &w, "torch.conv2d"),
    );
    (a, b)
}

/// hf-39072 (A) — inefficient memory resharding in the attention layer.
fn hf_resharding(rng: &mut Prng) -> (SysRun, SysRun) {
    let params = llm::TransformerParams::new(rng, llm::LlmSpec::gpt2_sim());
    let bad = llm::LlmBuildOpts { layout_roundtrip: false, ..llm::LlmBuildOpts::hf() }; // HND + contiguous copies
    let good = llm::LlmBuildOpts { hnd_layout: false, ..bad.clone() };
    let env = llm::default_env(SystemId::MiniHf);
    let a = SysRun::new("hf(HND reshard)", llm::hf_dispatcher(), env.clone(), llm::build_llm(&params, &bad));
    let b = SysRun::new("hf(NHD direct)", llm::hf_dispatcher(), env, llm::build_llm(&params, &good));
    (a, b)
}

/// jax-29875 (A) — cuDNN grouped-conv kernels are inefficient.
fn jax_grouped_conv(rng: &mut Prng) -> (SysRun, SysRun) {
    let spec = fw::ConvSpec::grouped();
    let (x, w) = fw::conv_params(rng, spec);
    let a = SysRun::new(
        "jax(grouped)",
        fw::jax_dispatcher(),
        Env::new().with("groups", "4"),
        fw::build_conv("jax", spec, fw::ConvLayout::Nchw, &x, &w, "jax.conv2d"),
    );
    let b = SysRun::new(
        "pytorch(grouped, channels-last)",
        fw::torch_dispatcher(),
        Env::new(),
        fw::build_conv("torch", spec, fw::ConvLayout::Nhwc, &x, &w, "torch.conv2d"),
    );
    (a, b)
}

/// pytorch-153195 (M) — default math mode (TF32 off) is inefficient.
fn default_math_mode(rng: &mut Prng) -> (SysRun, SysRun) {
    let spec = llm::LlmSpec { batch: 2, seq: 64, d_model: 256, n_heads: 8, d_ff: 1024, vocab: 512, layers: 1 };
    let params = llm::TransformerParams::new(rng, spec);
    let opts = llm::LlmBuildOpts { layout_roundtrip: false, unfused_gelu: false, use_addmm: false, ..llm::LlmBuildOpts::hf() };
    let a = SysRun::new("pytorch(default math)", llm::hf_dispatcher(), Env::new(), llm::build_llm(&params, &opts));
    let b = SysRun::new("pytorch(tf32)", llm::hf_dispatcher(), Env::new().with("allow_tf32", "true"), llm::build_llm(&params, &opts));
    (a, b)
}

/// hf-38977 (R) — LM head processes redundant tokens.
fn lm_head_redundant(rng: &mut Prng) -> (SysRun, SysRun) {
    let params = llm::TransformerParams::new(rng, llm::LlmSpec::gpt2_sim());
    let env = llm::default_env(SystemId::MiniHf);
    let bad = llm::LlmBuildOpts { layout_roundtrip: false, lm_head_all_positions: true, ..llm::LlmBuildOpts::hf() };
    let good = llm::LlmBuildOpts { lm_head_all_positions: false, ..bad.clone() };
    let a = SysRun::new("hf(lm-head all)", llm::hf_dispatcher(), env.clone(), llm::build_llm(&params, &bad));
    let b = SysRun::new("hf(lm-head last)", llm::hf_dispatcher(), env, llm::build_llm(&params, &good));
    (a, b)
}

/// vllm-20174 (A) — default vLLM prefill attention can be inefficient
/// (discovered by comparing against HF on the same GPT-2 workload).
fn vllm_prefill(rng: &mut Prng) -> (SysRun, SysRun) {
    let params = llm::TransformerParams::new(rng, llm::LlmSpec::gpt2_sim());
    let a = SysRun::new(
        "vllm(default prefill)",
        llm::vllm_dispatcher(),
        llm::default_env(SystemId::MiniVllm).with("use_tensor_cores", "false"),
        llm::build_llm(&params, &llm::LlmBuildOpts::vllm()),
    );
    let b = SysRun::new(
        "hf(sdpa prefill)",
        llm::hf_dispatcher(),
        llm::default_env(SystemId::MiniHf),
        llm::build_llm(&params, &llm::LlmBuildOpts { layout_roundtrip: false, unfused_gelu: false, use_addmm: false, ..llm::LlmBuildOpts::hf() }),
    );
    (a, b)
}

/// tf-96396 (A) — TensorFlow's custom convolution kernels are
/// inefficient (under NHWC, vs PyTorch's cuDNN).
fn tf_custom_conv(rng: &mut Prng) -> (SysRun, SysRun) {
    let spec = fw::ConvSpec::fig5c();
    let (x, w) = fw::conv_params(rng, spec);
    let a = SysRun::new(
        "tf(custom nhwc)",
        fw::tf_dispatcher(),
        Env::new(),
        fw::build_conv("tf", spec, fw::ConvLayout::Nhwc, &x, &w, "tf.conv2d"),
    );
    let b = SysRun::new(
        "pytorch(cudnn nhwc)",
        fw::torch_dispatcher(),
        Env::new(),
        fw::build_conv("torch", spec, fw::ConvLayout::Nhwc, &x, &w, "torch.conv2d"),
    );
    (a, b)
}

/// hf-39073 (M) — default GELU backend is inefficient (5 kernels vs
/// vLLM's fused kernel; §6.3 reports 77.4 % on the operator, 12 % e2e).
fn gelu_backend(rng: &mut Prng) -> (SysRun, SysRun) {
    let params = llm::TransformerParams::new(rng, llm::LlmSpec::gpt2_sim());
    let env = llm::default_env(SystemId::MiniHf);
    let bad = llm::LlmBuildOpts { layout_roundtrip: false, ..llm::LlmBuildOpts::hf() };
    let good = llm::LlmBuildOpts { unfused_gelu: false, ..bad.clone() };
    let mut disp = llm::hf_dispatcher();
    disp.register(
        "hf.gelu",
        crate::dispatch::Routine::direct(
            "hf.gelu_new_fused",
            vec![crate::trace::Frame::cpp("transformers::activations")],
            crate::dispatch::KernelChoice::new("gelu_tanh_fused", crate::energy::ComputeUnit::Sfu),
        ),
    );
    let a = SysRun::new("hf(gelu default)", llm::hf_dispatcher(), env.clone(), llm::build_llm(&params, &bad));
    let b = SysRun::new("hf(gelu fused)", disp, env, llm::build_llm(&params, &good));
    (a, b)
}

/// All 8 new issues with Table 3 metadata.
pub fn all() -> Vec<Scenario> {
    vec![
        Scenario { id: "pytorch-157334", issue: "pytorch-157334", category: Category::Misconfiguration, description: "Conv2D is inefficient under NCHW layout", expect: "conv", paper_diff_pct: None, expect_undetected: false, build: conv_nchw },
        Scenario { id: "hf-39072", issue: "hf-39072", category: Category::ApiMisuse, description: "Inefficient memory resharding in the attention layer", expect: "contig", paper_diff_pct: None, expect_undetected: false, build: hf_resharding },
        Scenario { id: "jax-29875", issue: "jax-29875", category: Category::ApiMisuse, description: "cuDNN grouped-conv kernels are inefficient", expect: "conv", paper_diff_pct: None, expect_undetected: false, build: jax_grouped_conv },
        Scenario { id: "pytorch-153195", issue: "pytorch-153195", category: Category::Misconfiguration, description: "Default math mode is inefficient", expect: "allow_tf32", paper_diff_pct: None, expect_undetected: false, build: default_math_mode },
        Scenario { id: "hf-38977", issue: "hf-38977", category: Category::Redundant, description: "LMHead processes redundant tokens", expect: "lm_head", paper_diff_pct: None, expect_undetected: false, build: lm_head_redundant },
        Scenario { id: "vllm-20174", issue: "vllm-20174", category: Category::ApiMisuse, description: "Default vLLM prefill attention can be inefficient", expect: "attn", paper_diff_pct: None, expect_undetected: false, build: vllm_prefill },
        Scenario { id: "tf-96396", issue: "tf-96396", category: Category::ApiMisuse, description: "TensorFlow's custom convolution kernels are inefficient", expect: "conv", paper_diff_pct: None, expect_undetected: false, build: tf_custom_conv },
        Scenario { id: "hf-39073", issue: "hf-39073", category: Category::Misconfiguration, description: "Default GELU backend is inefficient", expect: "gelu", paper_diff_pct: None, expect_undetected: false, build: gelu_backend },
    ]
}
