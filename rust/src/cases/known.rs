//! The 16 known energy-waste cases (paper Table 1), reconstructed from
//! their published issue descriptions against the mini-system fleet.

use crate::coordinator::SysRun;
use crate::diagnose::Category;
use crate::dispatch::Env;
use crate::exec::Dispatcher;
use crate::graph::{Attrs, Graph, OpKind};
use crate::systems::frameworks as fw;
use crate::systems::imagegen as ig;
use crate::systems::llm;
use crate::systems::SystemId;
use crate::tensor::Tensor;
use crate::util::Prng;

use super::Scenario;

fn attrs(kvs: &[(&str, &str)]) -> Attrs {
    kvs.iter().map(|(k, v)| (k.to_string(), v.to_string())).collect()
}

fn llm_run(label: &str, params: &llm::TransformerParams, opts: &llm::LlmBuildOpts, disp: Dispatcher, env: Env) -> SysRun {
    SysRun::new(label, disp, env, llm::build_llm(params, opts))
}

/// c1 vllm-9471 — prefill attention with tensor cores disabled.
fn c1(rng: &mut Prng) -> (SysRun, SysRun) {
    // prefill-heavy workload: long sequences make attention (the
    // affected operator) a dominant energy consumer, as in the issue
    let spec = llm::LlmSpec { batch: 2, seq: 256, d_model: 128, n_heads: 8, d_ff: 256, vocab: 512, layers: 1 };
    let params = llm::TransformerParams::new(rng, spec);
    let base = llm::default_env(SystemId::MiniVllm);
    let a = llm_run("vllm(tc off)", &params, &llm::LlmBuildOpts::vllm(), llm::vllm_dispatcher(), base.clone().with("use_tensor_cores", "false"));
    let b = llm_run("vllm(tc on)", &params, &llm::LlmBuildOpts::vllm(), llm::vllm_dispatcher(), base);
    (a, b)
}

/// c2 vllm-10811 — decode attention incurs a redundant KV copy.
fn c2(rng: &mut Prng) -> (SysRun, SysRun) {
    // decode-shaped attention micro-graph: q over a cached KV block.
    // Both sides see the SAME tensors (identical workload).
    let q = Tensor::randn(rng, &[1, 8, 16, 32]);
    let k = Tensor::randn(rng, &[1, 8, 256, 32]);
    let v = Tensor::randn(rng, &[1, 8, 256, 32]);
    let build = |with_copy: bool, q: Tensor, k: Tensor, v: Tensor| {
        let mut g = Graph::new(if with_copy { "vllm-decode-copy" } else { "vllm-decode" });
        let qi = g.add(OpKind::Input, &[], "q");
        let ki = g.add(OpKind::Input, &[], "kv_cache_k");
        let vi = g.add(OpKind::Input, &[], "kv_cache_v");
        let (ku, vu) = if with_copy {
            (
                g.add(OpKind::Copy, &[ki], "decode.kv_k_copy"),
                g.add(OpKind::Copy, &[vi], "decode.kv_v_copy"),
            )
        } else {
            (ki, vi)
        };
        let at = attrs(&[("dispatch", "vllm.decode_attention")]);
        let o = g.add_attrs(OpKind::Attention, &[qi, ku, vu], "decode.attn", at);
        g.add(OpKind::Output, &[o], "out");
        let mut p = crate::exec::Program::new(g);
        p.feed(0, q);
        p.feed(1, k);
        p.feed(2, v);
        p
    };
    let env = llm::default_env(SystemId::MiniVllm);
    let a = SysRun::new("vllm-10811", llm::vllm_dispatcher(), env.clone(), build(true, q.clone(), k.clone(), v.clone()));
    let b = SysRun::new("vllm-fixed", llm::vllm_dispatcher(), env, build(false, q, k, v));
    (a, b)
}

/// c3 sglang-5128 — top-k via full sort + slice.
fn c3(rng: &mut Prng) -> (SysRun, SysRun) {
    let params = llm::TransformerParams::new(rng, llm::LlmSpec::gpt2_sim());
    let env = llm::default_env(SystemId::MiniSglang);
    let bad = llm::LlmBuildOpts { topk: Some(llm::TopkImpl::SortSlice), ..llm::LlmBuildOpts::sglang() };
    let good = llm::LlmBuildOpts { topk: Some(llm::TopkImpl::Fused), ..llm::LlmBuildOpts::sglang() };
    let a = llm_run("sglang(sort-topk)", &params, &bad, llm::sglang_dispatcher(), env.clone());
    let b = llm_run("sglang(fused-topk)", &params, &good, llm::sglang_dispatcher(), env);
    (a, b)
}

/// c4 megatron-543 — redundant repeat_interleave in GQA.
fn c4(rng: &mut Prng) -> (SysRun, SysRun) {
    let params = llm::TransformerParams::new(rng, llm::LlmSpec::gpt2_sim());
    let env = llm::default_env(SystemId::MiniMegatron);
    let bad = llm::LlmBuildOpts::megatron(); // materialised repeat
    let good = llm::LlmBuildOpts { gqa_fused: true, ..llm::LlmBuildOpts::megatron() };
    let a = llm_run("megatron(repeat)", &params, &bad, llm::megatron_dispatcher(), env.clone());
    let b = llm_run("megatron(fused-gqa)", &params, &good, llm::megatron_dispatcher(), env);
    (a, b)
}

/// c5 hf-14450 — default tensor format causes layout transformations.
fn c5(rng: &mut Prng) -> (SysRun, SysRun) {
    let params = llm::TransformerParams::new(rng, llm::LlmSpec::gpt2_sim());
    let env = llm::default_env(SystemId::MiniHf);
    let bad = llm::LlmBuildOpts::hf(); // layout_roundtrip = true
    let good = llm::LlmBuildOpts { layout_roundtrip: false, ..llm::LlmBuildOpts::hf() };
    let a = llm_run("hf(default fmt)", &params, &bad, llm::hf_dispatcher(), env.clone());
    let b = llm_run("hf(channels-last)", &params, &good, llm::hf_dispatcher(), env);
    (a, b)
}

/// c6 hf-34570 — torch.linalg.eigvals picks the general solver for
/// symmetric inputs.
fn c6(rng: &mut Prng) -> (SysRun, SysRun) {
    let m = Tensor::randn(rng, &[96, 96]);
    // symmetrise so both paths see a symmetric input
    let sym = crate::tensor::ops::scale(&crate::tensor::ops::add(&m, &m.t().contiguous()), 0.5);
    let a_prog = fw::build_unary_op("torch", OpKind::Eigvals, "spectrum.eigvals", attrs(&[("dispatch", "torch.linalg.eigvals")]), &sym, &[]);
    let b_prog = fw::build_unary_op("torch", OpKind::Eigvals, "spectrum.eigvalsh", attrs(&[("dispatch", "torch.linalg.eigvalsh")]), &sym, &[]);
    let mut disp_b = fw::torch_dispatcher();
    disp_b.register(
        "torch.linalg.eigvalsh",
        crate::dispatch::Routine::direct(
            "torch.linalg.eigvalsh",
            vec![crate::trace::Frame::cpp("at::native::linalg_eigh")],
            crate::dispatch::KernelChoice::new("cusolver_syevd", crate::energy::ComputeUnit::CudaCore),
        ),
    );
    let a = SysRun::new("hf-34570", fw::torch_dispatcher(), Env::new(), a_prog);
    let b = SysRun::new("eigvalsh", disp_b, Env::new(), b_prog);
    (a, b)
}

/// c7 diffusers-12131 — unnecessary concat/split around the skip add.
fn c7(rng: &mut Prng) -> (SysRun, SysRun) {
    let params = ig::UnetParams::new(rng, ig::UnetSpec::sd3_sim());
    let a = SysRun::new(
        "diffusers(concat-split)",
        ig::diffusers_dispatcher(),
        ig::sd_env(true),
        ig::build_unet_block(&params, &ig::UnetBuildOpts::diffusers()),
    );
    let b = SysRun::new(
        "sd(direct add)",
        ig::sd_dispatcher(),
        ig::sd_env(true),
        ig::build_unet_block(&params, &ig::UnetBuildOpts::sd()),
    );
    (a, b)
}

/// c8 sd-279 — allow_tf32 left disabled.
fn c8(rng: &mut Prng) -> (SysRun, SysRun) {
    let params = ig::UnetParams::new(rng, ig::UnetSpec::sd3_sim());
    let a = SysRun::new(
        "sd(tf32 off)",
        ig::sd_dispatcher(),
        ig::sd_env(false),
        ig::build_unet_block(&params, &ig::UnetBuildOpts::sd()),
    );
    let b = SysRun::new(
        "sd(tf32 on)",
        ig::sd_dispatcher(),
        ig::sd_env(true),
        ig::build_unet_block(&params, &ig::UnetBuildOpts::sd()),
    );
    (a, b)
}

/// c9 pytorch-181115 — dist.Join keeps the finished GPU spinning.
fn c9(rng: &mut Prng) -> (SysRun, SysRun) {
    // the light rank's iteration: compute + (join barrier | nothing)
    let h = 512;
    let batch = 160;
    let x = Tensor::randn(rng, &[batch, h]);
    let w1 = Tensor::randn(rng, &[h, h]);
    let build = |with_join: bool, xt: Tensor, wt: Tensor| {
        let mut g = Graph::new(if with_join { "ddp-join" } else { "ddp-early-exit" });
        let x = g.add(OpKind::Input, &[], "batch");
        let w1 = g.add(OpKind::Weight, &[], "w1");
        let m = g.add(OpKind::MatMul, &[x, w1], "mlp.fc1");
        let ar = g.add(OpKind::AllReduce, &[m], "ddp.all_reduce");
        let out = if with_join {
            let at = attrs(&[("wait_us", "400"), ("power_frac", "0.45")]);
            g.add_attrs(OpKind::Barrier, &[ar], "dist.Join.barrier", at)
        } else {
            ar
        };
        g.add(OpKind::Output, &[out], "out");
        let mut p = crate::exec::Program::new(g);
        p.feed(0, xt);
        p.feed(1, wt);
        p
    };
    let a = SysRun::new("pytorch(dist.Join)", Dispatcher::new(), Env::new(), build(true, x.clone(), w1.clone()));
    let b = SysRun::new("pytorch(early-exit)", Dispatcher::new(), Env::new(), build(false, x, w1));
    (a, b)
}

/// c10 pytorch-141210 — torch.addmm selects higher-energy kernels.
fn c10(rng: &mut Prng) -> (SysRun, SysRun) {
    // single-layer GPT-2, batch 8, len 1024 scaled: the Fig 2 workload
    let spec = llm::LlmSpec { batch: 2, seq: 128, d_model: 256, n_heads: 8, d_ff: 1024, vocab: 1024, layers: 1 };
    let params = llm::TransformerParams::new(rng, spec);
    let env = llm::default_env(SystemId::MiniHf);
    let bad = llm::LlmBuildOpts { layout_roundtrip: false, unfused_gelu: false, ..llm::LlmBuildOpts::hf() };
    let good = llm::LlmBuildOpts { use_addmm: false, ..bad.clone() };
    let a = llm_run("hf(addmm)", &params, &bad, llm::hf_dispatcher(), env.clone());
    let b = llm_run("hf(add+mm)", &params, &good, llm::hf_dispatcher(), env);
    (a, b)
}

/// c11 pytorch-28224 — CPU busy-wait flags; GPU energy unaffected, so
/// Magneton (a GPU energy profiler) is expected to miss it.
fn c11(rng: &mut Prng) -> (SysRun, SysRun) {
    let xt = Tensor::randn(rng, &[64, 128]);
    let wt = Tensor::randn(rng, &[128, 128]);
    let build = |xt: Tensor, wt: Tensor| {
        let mut g = Graph::new("cpu-busywait");
        let x = g.add(OpKind::Input, &[], "x");
        let w = g.add(OpKind::Weight, &[], "w");
        let m = g.add(OpKind::MatMul, &[x, w], "proj");
        g.add(OpKind::Output, &[m], "out");
        let mut p = crate::exec::Program::new(g);
        p.feed(0, xt);
        p.feed(1, wt);
        p
    };
    // the CUDA_LAUNCH_BLOCKING-style flag changes only CPU behaviour
    let a = SysRun::new("pytorch(spin-wait)", Dispatcher::new(), Env::new().with("cudaDeviceScheduleSpin", "true"), build(xt.clone(), wt.clone()));
    let b = SysRun::new("pytorch(yield-wait)", Dispatcher::new(), Env::new(), build(xt, wt));
    (a, b)
}

/// c12 pytorch-76012 — non-contiguous LayerNorm input.
fn c12(rng: &mut Prng) -> (SysRun, SysRun) {
    let x = Tensor::randn(rng, &[128, 64, 32]);
    let gamma = Tensor::full(&[32], 1.0);
    let beta = Tensor::zeros(&[32]);
    let build = |contig: bool| {
        let mut g = Graph::new(if contig { "ln-contig" } else { "ln-strided" });
        let xi = g.add(OpKind::Input, &[], "x");
        let gi = g.add(OpKind::Weight, &[], "gamma");
        let bi = g.add(OpKind::Weight, &[], "beta");
        // upstream transpose makes the input non-contiguous
        let p = g.add_attr1(OpKind::Permute, &[xi], "upstream.transpose", "perm", "1,0,2");
        let ln_in = if contig {
            g.add(OpKind::Contiguous, &[p], "fix.contiguous")
        } else {
            p
        };
        let at = attrs(&[
            ("dispatch", "torch.nn.functional.layer_norm"),
            ("input_contiguous", if contig { "true" } else { "false" }),
        ]);
        let o = g.add_attrs(OpKind::LayerNorm, &[ln_in, gi, bi], "model.layer_norm", at);
        g.add(OpKind::Output, &[o], "out");
        let mut prog = crate::exec::Program::new(g);
        prog.feed(0, x.clone());
        prog.feed(1, gamma.clone());
        prog.feed(2, beta.clone());
        prog
    };
    let a = SysRun::new("pytorch-76012", fw::torch_dispatcher(), Env::new(), build(false));
    let b = SysRun::new("contig-first", fw::torch_dispatcher(), Env::new(), build(true));
    (a, b)
}

/// c13 pytorch-141822 — F.cross_entropy launches pricier kernels.
fn c13(rng: &mut Prng) -> (SysRun, SysRun) {
    let logits = Tensor::randn(rng, &[512, 512]);
    let targets: Vec<String> = (0..512).map(|i| (i % 512).to_string()).collect();
    let at = attrs(&[("dispatch", "torch.nn.functional.cross_entropy")]);
    let mut at = at;
    at.insert("targets".into(), targets.join(","));
    let prog = |a: Attrs| fw::build_unary_op("torch", OpKind::CrossEntropy, "loss.cross_entropy", a, &logits, &[]);
    let a = SysRun::new("pytorch-141822", fw::torch_dispatcher(), Env::new(), prog(at.clone()));
    let b = SysRun::new("fused-logsoftmax", fw::torch_dispatcher(), Env::new().with("fused_log_softmax", "true"), prog(at));
    (a, b)
}

/// c14 jax-28614 — jax.scipy.signal.stft lowers to inefficient FFTs.
fn c14(rng: &mut Prng) -> (SysRun, SysRun) {
    let signal = Tensor::randn(rng, &[32768]);
    let at = attrs(&[("dispatch", "jax.stft"), ("frame", "256"), ("hop", "64")]);
    let prog = |a: Attrs| fw::build_unary_op("jax", OpKind::Stft, "signal.stft", a, &signal, &[]);
    let a = SysRun::new("jax-28614", fw::jax_dispatcher(), Env::new(), prog(at.clone()));
    let b = SysRun::new("rfft-path", fw::jax_dispatcher(), Env::new().with("use_rfft", "true"), prog(at));
    (a, b)
}

/// c15 jax-9239 — redundant computations in jax.scipy.linalg.expm.
fn c15(rng: &mut Prng) -> (SysRun, SysRun) {
    let m = crate::tensor::ops::scale(&Tensor::randn(rng, &[160, 160]), 0.05);
    let at = attrs(&[("dispatch", "jax.expm")]);
    let prog = |a: Attrs| fw::build_unary_op("jax", OpKind::Expm, "linalg.expm", a, &m, &[]);
    let a = SysRun::new("jax-9239", fw::jax_dispatcher(), Env::new(), prog(at.clone()));
    let b = SysRun::new("hoisted-powers", fw::jax_dispatcher(), Env::new().with("reuse_powers", "true"), prog(at));
    (a, b)
}

/// c16 tf-60772 — count_nonzero makes implicit cast copies.
fn c16(rng: &mut Prng) -> (SysRun, SysRun) {
    let x = Tensor::randn(rng, &[1024, 512]);
    let at = attrs(&[("dispatch", "tf.count_nonzero")]);
    let prog = |a: Attrs| fw::build_unary_op("tf", OpKind::CountNonzero, "metrics.count_nonzero", a, &x, &[]);
    let a = SysRun::new("tf-60772", fw::tf_dispatcher(), Env::new(), prog(at.clone()));
    let b = SysRun::new("direct-reduce", fw::tf_dispatcher(), Env::new().with("direct_reduce", "true"), prog(at));
    (a, b)
}

/// All 16 known cases with metadata mirroring Table 1/2.
pub fn all() -> Vec<Scenario> {
    vec![
        Scenario { id: "c1", issue: "vllm-9471", category: Category::Misconfiguration, description: "Prefill attention consumes more energy with tensor cores disabled", expect: "use_tensor_cores", paper_diff_pct: Some(12.6), expect_undetected: false, build: c1 },
        Scenario { id: "c2", issue: "vllm-10811", category: Category::Redundant, description: "Decode attention incurs energy waste via redundant data copy", expect: "copy", paper_diff_pct: Some(1.4), expect_undetected: false, build: c2 },
        Scenario { id: "c3", issue: "sglang-5128", category: Category::ApiMisuse, description: "Top-k implementation launches energy-inefficient APIs", expect: "sort", paper_diff_pct: Some(2.5), expect_undetected: false, build: c3 },
        Scenario { id: "c4", issue: "megatron-543", category: Category::Redundant, description: "Redundant repeat_interleave results in energy waste", expect: "repeat_interleave", paper_diff_pct: Some(6.7), expect_undetected: false, build: c4 },
        Scenario { id: "c5", issue: "hf-14450", category: Category::Misconfiguration, description: "Default tensor format causes energy-intensive layout transformations", expect: "fmt_copy", paper_diff_pct: Some(58.8), expect_undetected: false, build: c5 },
        Scenario { id: "c6", issue: "hf-34570", category: Category::ApiMisuse, description: "torch.linalg.eigvals selects energy-inefficient kernels", expect: "eigvals", paper_diff_pct: Some(29.1), expect_undetected: false, build: c6 },
        Scenario { id: "c7", issue: "diffusers-12131", category: Category::ApiMisuse, description: "Unnecessary concat/split ops consume extra memory access energy", expect: "concat", paper_diff_pct: Some(6.1), expect_undetected: false, build: c7 },
        Scenario { id: "c8", issue: "sd-279", category: Category::Misconfiguration, description: "Linear layers fail to utilize energy-efficient tensor core instructions", expect: "allow_tf32", paper_diff_pct: Some(12.5), expect_undetected: false, build: c8 },
        Scenario { id: "c9", issue: "pytorch-181115", category: Category::Redundant, description: "dist.Join prevents a finished GPU from going to idle mode", expect: "Join", paper_diff_pct: Some(7.0), expect_undetected: false, build: c9 },
        Scenario { id: "c10", issue: "pytorch-141210", category: Category::ApiMisuse, description: "torch.addmm selects kernels with higher energy consumption", expect: "addmm", paper_diff_pct: Some(9.1), expect_undetected: false, build: c10 },
        Scenario { id: "c11", issue: "pytorch-28224", category: Category::Misconfiguration, description: "Suboptimal flags cause CPU busy-waiting, preventing low-power states", expect: "", paper_diff_pct: None, expect_undetected: true, build: c11 },
        Scenario { id: "c12", issue: "pytorch-76012", category: Category::ApiMisuse, description: "Non-contiguous inputs in LayerNorm trigger inefficient access patterns", expect: "layer_norm", paper_diff_pct: Some(16.3), expect_undetected: false, build: c12 },
        Scenario { id: "c13", issue: "pytorch-141822", category: Category::ApiMisuse, description: "F.cross_entropy launches kernels with higher energy consumption", expect: "cross_entropy", paper_diff_pct: Some(2.6), expect_undetected: false, build: c13 },
        Scenario { id: "c14", issue: "jax-28614", category: Category::ApiMisuse, description: "jax.scipy.signal.stft calls inefficient low-level APIs", expect: "stft", paper_diff_pct: Some(7.7), expect_undetected: false, build: c14 },
        Scenario { id: "c15", issue: "jax-9239", category: Category::Redundant, description: "Redundant computations in jax.scipy.linalg.expm", expect: "expm", paper_diff_pct: Some(2.1), expect_undetected: false, build: c15 },
        Scenario { id: "c16", issue: "tf-60772", category: Category::ApiMisuse, description: "count_nonzero triggers implicit energy-inefficient data copies", expect: "count_nonzero", paper_diff_pct: Some(27.8), expect_undetected: false, build: c16 },
    ]
}
