//! The paper's evaluation case library.
//!
//! [`known_cases`] reconstructs the 16 real-world energy-waste issues of
//! Table 1 (c1–c16); [`new_cases`] the 8 previously-unknown issues of
//! Table 3. Each scenario builds two runnable system configurations —
//! the wasteful variant and its efficient peer — following the published
//! issue's description, plus ground truth for scoring detection and
//! diagnosis (Table 2).

pub mod known;
pub mod new_issues;

use crate::coordinator::SysRun;
use crate::diagnose::Category;
use crate::util::Prng;

/// A reconstructed energy-waste scenario.
pub struct Scenario {
    /// Paper id, e.g. `c10` or `pytorch-157334`.
    pub id: &'static str,
    /// Upstream issue reference, e.g. `pytorch-141210`.
    pub issue: &'static str,
    /// Paper's category for the case.
    pub category: Category,
    pub description: &'static str,
    /// Substring that must appear in the diagnosis subject/suggestion
    /// for the case to count as *diagnosed* (the root-cause check).
    pub expect: &'static str,
    /// Paper-reported end-to-end energy diff (Table 2 "Diff."), when
    /// available; used in EXPERIMENTS.md paper-vs-measured rows.
    pub paper_diff_pct: Option<f64>,
    /// True for c11: the issue is CPU-side and Magneton is expected to
    /// miss it (GPU energy unaffected).
    pub expect_undetected: bool,
    /// Build (wasteful, efficient) runs.
    pub build: fn(&mut Prng) -> (SysRun, SysRun),
}

/// All 16 known cases (Table 1/2).
pub fn known_cases() -> Vec<Scenario> {
    known::all()
}

/// All 8 new issues (Table 3).
pub fn new_cases() -> Vec<Scenario> {
    new_issues::all()
}

/// Find a case by id across both libraries.
pub fn by_id(id: &str) -> Option<Scenario> {
    known_cases()
        .into_iter()
        .chain(new_cases())
        .find(|s| s.id == id || s.issue == id)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sixteen_known_eight_new() {
        assert_eq!(known_cases().len(), 16);
        assert_eq!(new_cases().len(), 8);
    }

    #[test]
    fn ids_unique() {
        let mut ids: Vec<&str> = known_cases().iter().map(|s| s.id).collect();
        ids.extend(new_cases().iter().map(|s| s.id));
        let n = ids.len();
        ids.sort();
        ids.dedup();
        assert_eq!(ids.len(), n);
    }

    #[test]
    fn lookup_by_id() {
        assert!(by_id("c10").is_some());
        assert!(by_id("pytorch-157334").is_some());
        assert!(by_id("nonexistent").is_none());
    }

    #[test]
    fn all_cases_build_and_run() {
        // smoke: every scenario's two sides execute and produce energy
        let mag = crate::coordinator::Magneton::new(crate::energy::DeviceSpec::h200_sim());
        let mut rng = Prng::new(99);
        for s in known_cases().into_iter().chain(new_cases()) {
            let (a, b) = (s.build)(&mut rng);
            let ra = mag.run_side(&a);
            let rb = mag.run_side(&b);
            assert!(ra.total_energy_j > 0.0, "{}: A no energy", s.id);
            assert!(rb.total_energy_j > 0.0, "{}: B no energy", s.id);
        }
    }
}
