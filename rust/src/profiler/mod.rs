//! Profiler baselines (paper §6.1): the PyTorch profiler (latency
//! ranking), Zeus (NVML-windowed energy), Zeus-replay (1000× operator
//! replay over NVML), and Magneton's own replay meter.
//!
//! All consume a finished [`RunArtifacts`]; energy-based profilers read
//! the run's ground-truth [`PowerTrace`] *through* their measurement
//! model, so their errors come from the mechanism (sampling rate,
//! latency, window limits), exactly as in Table 2/Table 4.

use crate::energy::sampler::{NvmlSampler, PhysicalMeter, WindowedMeter};
use crate::energy::PowerTrace;
use crate::exec::RunArtifacts;

/// A profiler's per-operator report row.
#[derive(Clone, Debug)]
pub struct OpReport {
    pub label: String,
    pub kernel: String,
    /// Metric the profiler ranks by (µs for PyTorch profiler, J else).
    pub value: f64,
    /// None when the profiler could not measure this op (e.g. window
    /// shorter than the Zeus minimum).
    pub measured: bool,
}

/// Rank (1-based) of the first row whose label contains `needle`, among
/// rows sorted by value descending. `None` if absent/unmeasured.
pub fn rank_of(rows: &[OpReport], needle: &str) -> Option<usize> {
    let mut sorted: Vec<&OpReport> = rows.iter().collect();
    sorted.sort_by(|a, b| b.value.total_cmp(&a.value));
    sorted
        .iter()
        .position(|r| r.measured && r.label.contains(needle))
        .map(|p| p + 1)
}

/// PyTorch-profiler baseline: operator latency ranking (key_averages()).
/// Detects perf problems, not energy ones — the addmm-style cases rank
/// low here because they are barely slower.
pub fn pytorch_profiler(arts: &RunArtifacts) -> Vec<OpReport> {
    arts.records
        .iter()
        .map(|r| OpReport {
            label: r.label.clone(),
            kernel: r.kernel.clone(),
            value: r.time_us,
            measured: true,
        })
        .collect()
}

/// Zeus baseline: wrap each operator in a begin/end window read through
/// NVML. Operators shorter than the 100 ms minimum window are
/// unmeasurable (the paper: Zeus can profile only c6, whose kernel runs
/// longer than the window).
pub fn zeus(arts: &RunArtifacts) -> Vec<OpReport> {
    let meter = WindowedMeter::default();
    let mut t = 0.0;
    arts.records
        .iter()
        .map(|r| {
            let w = meter.measure(&arts.power, t, t + r.time_us);
            t += r.time_us;
            OpReport {
                label: r.label.clone(),
                kernel: r.kernel.clone(),
                value: if w.reliable { w.energy_j } else { 0.0 },
                measured: w.reliable,
            }
        })
        .collect()
}

/// Replay an operator `n` times back-to-back and measure the stretched
/// window through NVML, dividing by `n`. This is what both Zeus-replay
/// and Magneton's software mode do; accuracy grows with the window
/// length relative to the NVML sample period.
pub fn replay_energy(record_time_us: f64, record_power_w: f64, idle_w: f64, n: usize, nvml: &NvmlSampler) -> f64 {
    replay_energy_ex(record_time_us, record_power_w, idle_w, n, nvml, false)
}

/// Like [`replay_energy`], with Magneton's *adaptive* mode: the replay
/// count is raised until the stretched window spans enough NVML sample
/// periods to "average out delays and stabilize readings" (paper §5.2).
/// Zeus-replay uses the fixed 1000-iteration loop of the paper's setup.
pub fn replay_energy_ex(
    record_time_us: f64,
    record_power_w: f64,
    idle_w: f64,
    n: usize,
    nvml: &NvmlSampler,
    adaptive: bool,
) -> f64 {
    let n = if adaptive {
        // window must cover ~50 sample periods past the counter latency
        let min_window_us = 50.0 * 1e6 / nvml.sample_hz + nvml.latency_us;
        n.max((min_window_us / record_time_us.max(1e-3)).ceil() as usize)
    } else {
        n
    };
    // Build the replay trace: a settling period then n repetitions.
    let mut trace = PowerTrace::new(idle_w);
    trace.push(300_000.0, idle_w); // settle
    let t0 = trace.now_us();
    for _ in 0..n {
        trace.push(record_time_us, record_power_w);
    }
    let t1 = trace.now_us();
    // let the delayed counter catch up before reading
    trace.push(400_000.0, idle_w);
    let e = nvml.energy_j(&trace, t0, t1 + nvml.latency_us);
    // subtract the idle tail we included for catch-up
    let tail = idle_w * nvml.latency_us * 1e-6;
    ((e - tail) / n as f64).max(0.0)
}

/// Zeus-replay baseline: 1000× replay per op (paper setup). Reported
/// per-op energies become usable, but no root-cause information.
pub fn zeus_replay(arts: &RunArtifacts, replays: usize) -> Vec<OpReport> {
    let nvml = NvmlSampler::default();
    arts.records
        .iter()
        .map(|r| OpReport {
            label: r.label.clone(),
            kernel: r.kernel.clone(),
            value: replay_energy(r.time_us, r.avg_power_w, arts.power.idle_w, replays, &nvml),
            measured: true,
        })
        .collect()
}

/// Magneton's meter: physical power meter when available (exact
/// integration), otherwise operator replay tuned to span several NVML
/// sample periods (paper §5.2).
pub enum MagnetonMeter {
    Physical,
    Replay { replays: usize },
}

impl MagnetonMeter {
    pub fn per_op(&self, arts: &RunArtifacts) -> Vec<OpReport> {
        match self {
            MagnetonMeter::Physical => {
                let meter = PhysicalMeter;
                let mut t = 0.0;
                arts.records
                    .iter()
                    .map(|r| {
                        let e = meter.energy_j(&arts.power, t, t + r.time_us);
                        t += r.time_us;
                        OpReport { label: r.label.clone(), kernel: r.kernel.clone(), value: e, measured: true }
                    })
                    .collect()
            }
            MagnetonMeter::Replay { replays } => {
                let nvml = NvmlSampler::default();
                arts.records
                    .iter()
                    .map(|r| OpReport {
                        label: r.label.clone(),
                        kernel: r.kernel.clone(),
                        value: replay_energy_ex(
                            r.time_us,
                            r.avg_power_w,
                            arts.power.idle_w,
                            *replays,
                            &nvml,
                            true,
                        ),
                        measured: true,
                    })
                    .collect()
            }
        }
    }

    /// Per-op average power (Table 4 columns).
    pub fn power_of(&self, arts: &RunArtifacts, label_needle: &str) -> Option<f64> {
        let rows = self.per_op(arts);
        let rec = arts.records.iter().find(|r| r.label.contains(label_needle))?;
        let row = rows.iter().find(|r| r.label.contains(label_needle))?;
        Some(row.value / (rec.time_us * 1e-6))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dispatch::Env;
    use crate::energy::DeviceSpec;
    use crate::exec::{Dispatcher, Executor, Program};
    use crate::graph::{Graph, OpKind};
    use crate::tensor::Tensor;
    use crate::util::Prng;

    fn run() -> RunArtifacts {
        let mut rng = Prng::new(13);
        let mut g = Graph::new("p");
        let x = g.add(OpKind::Input, &[], "x");
        let w = g.add(OpKind::Weight, &[], "w");
        let m = g.add(OpKind::MatMul, &[x, w], "linear");
        let s = g.add(OpKind::Softmax, &[m], "softmax");
        g.add(OpKind::Output, &[s], "out");
        let mut p = Program::new(g);
        p.feed(0, Tensor::randn(&mut rng, &[64, 128]));
        p.feed(1, Tensor::randn(&mut rng, &[128, 64]));
        Executor::new(DeviceSpec::h200_sim(), Dispatcher::new(), Env::new()).run(&p)
    }

    #[test]
    fn pytorch_profiler_ranks_by_latency() {
        let arts = run();
        let rows = pytorch_profiler(&arts);
        assert_eq!(rows.len(), 2);
        assert!(rank_of(&rows, "linear").is_some());
    }

    #[test]
    fn zeus_cannot_measure_microsecond_kernels() {
        let arts = run();
        let rows = zeus(&arts);
        // every op here is far below the 100 ms window
        assert!(rows.iter().all(|r| !r.measured));
        assert!(rank_of(&rows, "linear").is_none());
    }

    #[test]
    fn replay_recovers_true_energy_within_5pct() {
        // a 2 ms 400 W kernel: truth = 0.8 mJ
        let nvml = NvmlSampler::default();
        let e = replay_energy(2000.0, 400.0, 90.0, 1000, &nvml);
        let truth = 400.0 * 2000.0 * 1e-6;
        let err = (e - truth).abs() / truth;
        assert!(err < 0.05, "replay error {err} (est {e}, truth {truth})");
    }

    #[test]
    fn few_replays_are_less_accurate_than_many() {
        let nvml = NvmlSampler::default();
        let truth = 350.0 * 500.0 * 1e-6;
        let few = (replay_energy(500.0, 350.0, 90.0, 3, &nvml) - truth).abs() / truth;
        let many = (replay_energy(500.0, 350.0, 90.0, 1000, &nvml) - truth).abs() / truth;
        assert!(many <= few + 1e-9, "many {many} vs few {few}");
    }

    /// Magneton's adaptive replay mode must actually shrink error vs a
    /// fixed small replay count on a sub-millisecond kernel: the fixed
    /// 3× window (~0.9 ms) spans no NVML sample period at all, while
    /// the adaptive mode stretches the window across ~50 periods.
    #[test]
    fn adaptive_replay_shrinks_error_on_submillisecond_kernel() {
        let nvml = NvmlSampler::default();
        let (time_us, power_w, idle_w) = (300.0, 400.0, 90.0);
        let truth = power_w * time_us * 1e-6;
        let fixed = replay_energy_ex(time_us, power_w, idle_w, 3, &nvml, false);
        let adaptive = replay_energy_ex(time_us, power_w, idle_w, 3, &nvml, true);
        let err_fixed = (fixed - truth).abs() / truth;
        let err_adaptive = (adaptive - truth).abs() / truth;
        assert!(
            err_adaptive < err_fixed,
            "adaptive {err_adaptive} not better than fixed {err_fixed}"
        );
        assert!(err_adaptive < 0.10, "adaptive error {err_adaptive} too large");
        assert!(err_fixed > 0.30, "fixed-3 error {err_fixed} unexpectedly small");
    }

    /// The incremental sampler keeps the 1000× replay meter's accuracy
    /// unchanged (it is bit-identical to the old path) — spot-check the
    /// replay estimate against the rescan reference end to end.
    #[test]
    fn replay_meter_identical_through_cursor_and_rescan() {
        let nvml = NvmlSampler::default();
        let (time_us, power_w, idle_w, n) = (2000.0, 400.0, 90.0, 200usize);
        // rebuild the replay trace exactly as replay_energy_ex does
        let mut trace = PowerTrace::new(idle_w);
        trace.push(300_000.0, idle_w);
        let t0 = trace.now_us();
        for _ in 0..n {
            trace.push(time_us, power_w);
        }
        let t1 = trace.now_us();
        trace.push(400_000.0, idle_w);
        let through_cursor = nvml.energy_j(&trace, t0, t1 + nvml.latency_us);
        let through_rescan = nvml.energy_j_rescan(&trace, t0, t1 + nvml.latency_us);
        assert_eq!(through_cursor.to_bits(), through_rescan.to_bits());
    }

    #[test]
    fn magneton_physical_meter_matches_records() {
        let arts = run();
        let rows = MagnetonMeter::Physical.per_op(&arts);
        let total: f64 = rows.iter().map(|r| r.value).sum();
        let rel = (total - arts.total_energy_j).abs() / arts.total_energy_j;
        assert!(rel < 0.05, "physical {total} vs records {}", arts.total_energy_j);
    }
}
