//! Table 4 replica: per-operator power via (1) physical power meter
//! (ground truth), (2) Zeus/NVML windowed reads, (3) Magneton's
//! replay-based software mode.
//!
//! Paper shape: Zeus errs by up to −80 % on microsecond kernels (stale,
//! undersampled counter); Magneton replay lands within a few percent of
//! the physical meter.

use magneton::dispatch::Env;
use magneton::energy::sampler::{NvmlSampler, PhysicalMeter};
use magneton::energy::DeviceSpec;
use magneton::exec::{Dispatcher, Executor, Program};
use magneton::graph::{Attrs, Graph, OpKind};
use magneton::profiler::{replay_energy, replay_energy_ex};
use magneton::tensor::Tensor;
use magneton::util::bench::{banner, persist, persist_json};
use magneton::util::json::Json;
use magneton::util::table::Table;
use magneton::util::Prng;

fn main() {
    banner(
        "Table 4",
        "Per-op power: physical meter vs Zeus(NVML) vs Magneton replay (GPT-2-ish ops, testbed-A sim)",
    );
    // Testbed-A: RTX 4090-like device (as in the paper's accuracy study)
    let dev = DeviceSpec::rtx4090_sim();
    let mut rng = Prng::new(42);

    // a small graph exercising the paper's three ops: arange,
    // contiguous, linear (batch 256, len 128-ish)
    let mut g = Graph::new("table4");
    let x = g.add(OpKind::Input, &[], "x");
    let w = g.add(OpKind::Weight, &[], "w");
    let mut at = Attrs::new();
    at.insert("n".into(), "32768".into());
    g.add_attrs(OpKind::Arange, &[], "aten::arange", at);
    let p = g.add_attr1(OpKind::Permute, &[x], "transpose", "perm", "1,0");
    g.add(OpKind::Contiguous, &[p], "aten::contiguous");
    g.add(OpKind::MatMul, &[x, w], "aten::linear");
    let mut prog = Program::new(g);
    prog.feed(0, Tensor::randn(&mut rng, &[256, 512]));
    prog.feed(1, Tensor::randn(&mut rng, &[512, 512]));
    let exec = Executor::new(dev.clone(), Dispatcher::new(), Env::new());
    let arts = exec.run(&prog);

    let physical = PhysicalMeter;
    let nvml = NvmlSampler::default();
    let mut t = Table::new(vec![
        "Op", "Physical (W)", "Zeus (W)", "Zeus err%", "Magneton (W)", "Magneton err%",
    ]);
    let mut max_magneton_err: f64 = 0.0;
    let mut t_cursor = 0.0;
    for r in &arts.records {
        let (t0, t1) = (t_cursor, t_cursor + r.time_us);
        t_cursor = t1;
        let truth_w = physical.avg_power_w(&arts.power, t0, t1);
        // Zeus: windowed NVML read over the op's real (microsecond) window
        let zeus_w = nvml.avg_power_w(&arts.power, t0, t1);
        // Magneton replay: adaptively stretch the op to a stable window
        let replay_e = replay_energy_ex(r.time_us, r.avg_power_w, dev.idle_w, 1000, &nvml, true);
        let magneton_w = replay_e / (r.time_us * 1e-6);
        let zerr = (zeus_w - truth_w) / truth_w * 100.0;
        let merr = (magneton_w - truth_w) / truth_w * 100.0;
        max_magneton_err = max_magneton_err.max(merr.abs());
        t.row(vec![
            r.label.clone(),
            format!("{truth_w:.0}"),
            format!("{zeus_w:.0}"),
            format!("{zerr:+.1}%"),
            format!("{magneton_w:.0}"),
            format!("{merr:+.1}%"),
        ]);
        // the paper's shape: Zeus far below truth on short kernels
        assert!(zerr < -30.0, "Zeus unexpectedly accurate on {}: {zerr:.1}%", r.label);
    }
    let rendered = t.render();
    println!("{rendered}");
    let summary = format!(
        "max |Magneton replay error| = {max_magneton_err:.1}% (paper: <=4.1%); Zeus errs -30..-85% on microsecond kernels (paper: ~-72..-81%)"
    );
    println!("{summary}");
    persist("table4_accuracy", &format!("{rendered}\n{summary}\n"), Some(&t.to_csv()));
    persist_json(
        "BENCH_table4_accuracy",
        &Json::obj()
            .field("bench", "table4_accuracy")
            .field("max_magneton_err_pct", max_magneton_err)
            .build(),
    );
    assert!(max_magneton_err < 8.0, "Magneton replay error too large");
}
