//! Fig 8 replica: sensitivity of semantic-equivalence matching to the
//! comparison threshold ε.
//!
//! Ground truth for tensor-pair equivalence is computed with an
//! independent oracle (sorted-value multiset comparison — exact up to
//! the run's numeric noise, blind to layout), standing in for the
//! paper's manual annotation. We sweep ε from 1e-7 to 0.2 and report
//! F1; the paper's shape: F1 ≥ 0.8 across 1e-4…1.8e-2 and ≈1.0 in the
//! optimal band, degrading at both extremes.

use magneton::coordinator::Magneton;
use magneton::energy::DeviceSpec;
use magneton::fingerprint::RustMomentEngine;
use magneton::matching::find_equivalent_tensors;
use magneton::systems::llm;
use magneton::systems::SystemId;
use magneton::util::bench::{banner, persist, persist_json};
use magneton::util::json::Json;
use magneton::util::stats::f1_score;
use magneton::util::table::Table;
use magneton::util::Prng;

/// Independent oracle: two tensors are "truly" equivalent if their
/// sorted value multisets agree within 0.5 % (layout-blind, noise-aware).
fn ground_truth(a: &magneton::exec::RunArtifacts, b: &magneton::exec::RunArtifacts) -> std::collections::BTreeSet<(usize, usize)> {
    let mut sorted: Vec<Option<Vec<f32>>> = Vec::new();
    let sort_of = |arts: &magneton::exec::RunArtifacts, i: usize| -> Option<Vec<f32>> {
        let n = &arts.graph.nodes[i];
        // same anchor population as the matcher: activations only
        if n.op == magneton::graph::OpKind::Output || n.op == magneton::graph::OpKind::Weight {
            return None;
        }
        let t = arts.tensors[i].as_ref()?;
        if t.numel() < magneton::matching::MIN_ANCHOR_NUMEL {
            return None;
        }
        let mut v = t.to_vec();
        v.sort_by(f32::total_cmp);
        Some(v)
    };
    for i in 0..a.graph.len() {
        sorted.push(sort_of(a, i));
    }
    let sorted_b: Vec<Option<Vec<f32>>> = (0..b.graph.len()).map(|j| sort_of(b, j)).collect();
    let mut gt = std::collections::BTreeSet::new();
    for (i, si) in sorted.iter().enumerate() {
        let Some(si) = si else { continue };
        for (j, sj) in sorted_b.iter().enumerate() {
            let Some(sj) = sj else { continue };
            if si.len() != sj.len() {
                continue;
            }
            let scale = si.iter().fold(0.0f32, |m, x| m.max(x.abs())).max(1e-6);
            let close = si
                .iter()
                .zip(sj.iter())
                .all(|(x, y)| (x - y).abs() <= 0.005 * scale);
            if close {
                gt.insert((i, j));
            }
        }
    }
    gt
}

fn main() {
    banner("Fig 8", "F1 of equivalent-tensor matching vs threshold eps (paper: robust over 1e-4..1.8e-2)");
    let mag = Magneton::new(DeviceSpec::h200_sim());
    let mut rng = Prng::new(2026);

    // GPT-2 workload: HF vs vLLM (the paper's first sensitivity workload)
    let params = llm::TransformerParams::new(&mut rng, llm::LlmSpec::gpt2_sim());
    let a = magneton::coordinator::SysRun::new(
        "hf",
        llm::hf_dispatcher(),
        llm::default_env(SystemId::MiniHf),
        llm::build_llm(&params, &llm::LlmBuildOpts::hf()),
    );
    let b = magneton::coordinator::SysRun::new(
        "vllm",
        llm::vllm_dispatcher(),
        llm::default_env(SystemId::MiniVllm),
        llm::build_llm(&params, &llm::LlmBuildOpts::vllm()),
    );
    let ra = mag.run_side(&a);
    let rb = mag.run_side(&b);
    let gt = ground_truth(&ra, &rb);
    println!("ground-truth equivalent pairs: {}", gt.len());

    let mut t = Table::new(vec!["eps", "pairs", "TP", "FP", "FN", "F1"]);
    let mut csv = String::from("eps,f1\n");
    let mut band_ok = true;
    let mut best_f1: f64 = 0.0;
    for eps in [1e-7, 1e-6, 1e-5, 1e-4, 1e-3, 5e-3, 1.8e-2, 5e-2, 0.1, 0.2] {
        let eq = find_equivalent_tensors(&ra, &rb, eps, &RustMomentEngine);
        let tp = eq.pairs.iter().filter(|p| gt.contains(p)).count();
        let fp = eq.len() - tp;
        let fn_ = gt.len() - tp;
        let f1 = f1_score(tp, fp, fn_);
        best_f1 = best_f1.max(f1);
        if (1e-4..=1.8e-2).contains(&eps) && f1 < 0.8 {
            band_ok = false;
        }
        t.row(vec![
            format!("{eps:.0e}"),
            eq.len().to_string(),
            tp.to_string(),
            fp.to_string(),
            fn_.to_string(),
            format!("{f1:.3}"),
        ]);
        csv.push_str(&format!("{eps:e},{f1:.4}\n"));
    }
    let rendered = t.render();
    println!("{rendered}");
    let summary = format!(
        "best F1 {best_f1:.3}; F1 >= 0.8 across the paper's optimal band (1e-4..1.8e-2): {band_ok}"
    );
    println!("{summary}");
    persist("fig8_sensitivity", &format!("{rendered}\n{summary}\n"), Some(&csv));
    persist_json(
        "BENCH_fig8_sensitivity",
        &Json::obj()
            .field("bench", "fig8_sensitivity")
            .field("best_f1", best_f1)
            .field("band_ok", band_ok)
            .build(),
    );
    assert!(best_f1 > 0.85, "matching never reaches high F1");
    assert!(band_ok, "F1 dips below 0.8 inside the optimal band");
}
