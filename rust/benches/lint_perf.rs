//! Lint perf bench: static analysis must stay cheap relative to the
//! dynamic differential runs it front-runs. Times target construction
//! and the full lint suite at one worker and at the pool default, and
//! emits the shared `BENCH_lint.json` trajectory record so lint cost is
//! tracked commit-over-commit alongside the findings it produces.

use std::collections::BTreeMap;
use std::time::Duration;

use magneton::analysis::interact::search_node;
use magneton::analysis::{builtin_targets, interact_suite, lint_suite, InteractConfig, LintContext};
use magneton::dispatch::{Block, Env, KernelChoice, Routine, Term, VarSource};
use magneton::energy::{ComputeUnit, DeviceSpec};
use magneton::exec::{Dispatcher, Program};
use magneton::graph::{Graph, OpKind};
use magneton::tensor::Tensor;
use magneton::util::bench::{banner, bench, persist, persist_bench_json, BenchResult};
use magneton::util::json::Json;
use magneton::util::pool::default_threads;

/// Binary-tree routine over `k` config flags, every leaf its own
/// kernel choice — the worst case for the joint search, sized so the
/// branch-and-bound pruning has room to show (2^k joint outcomes).
fn tree_target(k: usize) -> (Program, Dispatcher) {
    let mut blocks = Vec::new();
    let mut choices = Vec::new();
    let mut provenance = BTreeMap::new();
    for i in 0..k {
        provenance.insert(format!("f{i:02}"), VarSource::ConfigFlag(format!("cfg.f{i:02}")));
    }
    for j in 0..(1usize << k) - 1 {
        let d = (usize::BITS - 1 - (j + 1).leading_zeros()) as usize;
        blocks.push(Block {
            func: "joint_dispatch".into(),
            term: Term::CondBranch {
                var: format!("f{d:02}"),
                eq: "true".into(),
                then_bb: 2 * j + 1,
                else_bb: 2 * j + 2,
            },
        });
    }
    for leaf in 0..(1usize << k) {
        let idx = choices.len();
        let frac = ((leaf as f64) * 0.618_033_988_749_895).fract();
        choices.push(
            KernelChoice::new(&format!("leaf_{leaf}"), ComputeUnit::TensorCore)
                .quality(0.4 + 0.6 * frac, 1.0, 1.0),
        );
        blocks.push(Block { func: "joint_dispatch".into(), term: Term::Launch { idx } });
    }
    let routine =
        Routine { api: "joint.tree".into(), frames: vec![], blocks, choices, provenance };
    let mut g = Graph::new("tree");
    let x = g.add(OpKind::Input, &[], "x");
    let w = g.add(OpKind::Weight, &[], "w");
    let m = g.add_attr1(OpKind::MatMul, &[x, w], "tree.proj", "dispatch", "joint.tree");
    g.add(OpKind::Output, &[m], "out");
    let mut p = Program::new(g);
    p.feed(0, Tensor::zeros(&[16, 32]));
    p.feed(1, Tensor::zeros(&[32, 16]));
    let mut d = Dispatcher::new();
    d.register("joint.tree", routine);
    (p, d)
}

fn main() {
    banner("Lint perf", "static energy lint over the built-in system programs");
    let device = DeviceSpec::h200_sim();
    let budget = Duration::from_millis(400);

    let build = bench("build targets (seed 7)", budget, || {
        std::hint::black_box(builtin_targets(7));
    });
    let targets = builtin_targets(7);
    let report = lint_suite(&targets, &device, 1);
    assert!(report.targets.iter().all(|t| t.error.is_none()), "builtin target failed lint");
    assert!(report.total_findings >= 5, "suite should surface findings");

    let threads = default_threads();
    let mut results: Vec<BenchResult> = vec![build];
    for (label, n) in [("lint suite (1 worker)", 1usize), ("lint suite (pool)", threads)] {
        results.push(bench(label, budget, || {
            std::hint::black_box(lint_suite(&targets, &device, n));
        }));
    }

    // interaction-search scaling: the whole suite (shallow routines,
    // flag slicing does the heavy lifting) and the worst-case deep
    // binary-tree routine where branch-and-bound pruning must carry
    let icfg = InteractConfig::default();
    let ireports = interact_suite(&targets, &device, 1, &icfg);
    assert!(ireports.iter().all(|r| r.error.is_none()), "builtin target failed interact");
    let diagnoses: usize = ireports.iter().map(|r| r.diagnoses.len()).sum();
    assert!(diagnoses >= 1, "joint target should yield an interaction diagnosis");
    for (label, n) in [("interact suite (1 worker)", 1usize), ("interact suite (pool)", threads)] {
        results.push(bench(label, budget, || {
            std::hint::black_box(interact_suite(&targets, &device, n, &icfg));
        }));
    }

    let mut tree_counts: Vec<(usize, usize, usize, usize)> = Vec::new();
    for k in [8usize, 10, 12] {
        let (p, d) = tree_target(k);
        let env = Env::new();
        let cx = LintContext::new(&p, &d, &env, &device).unwrap();
        let cfg = InteractConfig { max_joint_flags: k };
        let s = search_node(&cx, 2, &cfg).expect("tree routine is searchable");
        // the point of the pruning: strictly fewer joint outcomes priced
        // than the exhaustive sweep would have priced
        assert_eq!(s.stats.exhaustive, 1 << k);
        assert!(
            s.stats.evaluated < s.stats.exhaustive && s.stats.pruned > 0,
            "k={k}: evaluated {} !< exhaustive {} (pruned {})",
            s.stats.evaluated,
            s.stats.exhaustive,
            s.stats.pruned
        );
        tree_counts.push((k, s.stats.evaluated, s.stats.exhaustive, s.stats.pruned));
        results.push(bench(&format!("joint search (tree k={k})"), budget, || {
            std::hint::black_box(search_node(&cx, 2, &cfg));
        }));
    }

    let mut text = String::new();
    for r in &results {
        let line = r.report();
        println!("{line}");
        text.push_str(&line);
        text.push('\n');
    }
    println!(
        "\n{} targets, {} findings, est. {:.4} J wasted (pool = {threads} workers)",
        report.targets.len(),
        report.total_findings,
        report.total_est_wasted_j
    );
    for (k, evaluated, exhaustive, pruned) in &tree_counts {
        let line = format!(
            "joint search k={k}: evaluated {evaluated} of {exhaustive} joint outcomes \
             ({pruned} subtrees pruned)"
        );
        println!("{line}");
        text.push_str(&line);
        text.push('\n');
    }

    let deepest = *tree_counts.last().unwrap();
    persist("lint_perf", &text, None);
    persist_bench_json(
        "lint",
        &results,
        &[
            ("targets", Json::Num(report.targets.len() as f64)),
            ("findings", Json::Num(report.total_findings as f64)),
            ("est_wasted_j", Json::Num(report.total_est_wasted_j)),
            ("workers", Json::Num(threads as f64)),
            ("interact_diagnoses", Json::Num(diagnoses as f64)),
            ("interact_tree_flags", Json::Num(deepest.0 as f64)),
            ("interact_tree_evaluated", Json::Num(deepest.1 as f64)),
            ("interact_tree_exhaustive", Json::Num(deepest.2 as f64)),
            ("interact_tree_pruned", Json::Num(deepest.3 as f64)),
        ],
    );
}
