//! Lint perf bench: static analysis must stay cheap relative to the
//! dynamic differential runs it front-runs. Times target construction
//! and the full lint suite at one worker and at the pool default, and
//! emits the shared `BENCH_lint.json` trajectory record so lint cost is
//! tracked commit-over-commit alongside the findings it produces.

use std::time::Duration;

use magneton::analysis::{builtin_targets, lint_suite};
use magneton::energy::DeviceSpec;
use magneton::util::bench::{banner, bench, persist, persist_bench_json, BenchResult};
use magneton::util::json::Json;
use magneton::util::pool::default_threads;

fn main() {
    banner("Lint perf", "static energy lint over the built-in system programs");
    let device = DeviceSpec::h200_sim();
    let budget = Duration::from_millis(400);

    let build = bench("build targets (seed 7)", budget, || {
        std::hint::black_box(builtin_targets(7));
    });
    let targets = builtin_targets(7);
    let report = lint_suite(&targets, &device, 1);
    assert!(report.targets.iter().all(|t| t.error.is_none()), "builtin target failed lint");
    assert!(report.total_findings >= 5, "suite should surface findings");

    let threads = default_threads();
    let mut results: Vec<BenchResult> = vec![build];
    for (label, n) in [("lint suite (1 worker)", 1usize), ("lint suite (pool)", threads)] {
        results.push(bench(label, budget, || {
            std::hint::black_box(lint_suite(&targets, &device, n));
        }));
    }

    let mut text = String::new();
    for r in &results {
        let line = r.report();
        println!("{line}");
        text.push_str(&line);
        text.push('\n');
    }
    println!(
        "\n{} targets, {} findings, est. {:.4} J wasted (pool = {threads} workers)",
        report.targets.len(),
        report.total_findings,
        report.total_est_wasted_j
    );

    persist("lint_perf", &text, None);
    persist_bench_json(
        "lint",
        &results,
        &[
            ("targets", Json::Num(report.targets.len() as f64)),
            ("findings", Json::Num(report.total_findings as f64)),
            ("est_wasted_j", Json::Num(report.total_est_wasted_j)),
            ("workers", Json::Num(threads as f64)),
        ],
    );
}
