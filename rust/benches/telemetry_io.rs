//! Telemetry IO bench: NDJSON snapshot append and replay throughput,
//! plus the rotation invariant — on-disk usage must stay under the
//! byte budget no matter how many snapshots stream through the sink
//! (the disk-side analogue of the power-ring memory bound) — and the
//! follow lag: how fast a `Follower` catches up cold on a retained
//! directory and how cheap an incremental tail poll is.

use magneton::detect::Side;
use magneton::stream::{StreamFinding, WindowReport};
use magneton::telemetry::follow::Follower;
use magneton::telemetry::{load_dir, SinkConfig, Snapshot, SnapshotSink};
use magneton::util::bench::{banner, persist, persist_json, time_once};
use magneton::util::json::Json;
use magneton::util::table::Table;

/// A representative emitted window: one finding, realistic magnitudes.
fn window(seq: usize) -> WindowReport {
    WindowReport {
        seq,
        pairs: 250,
        energy_a_j: 1.5 + seq as f64 * 1e-3,
        energy_b_j: 1.2 + seq as f64 * 7e-4,
        time_a_us: 2.5e4,
        time_b_us: 2.5e4,
        findings: vec![StreamFinding {
            label: "serve.proj".into(),
            ops: 100,
            energy_a_j: 0.9,
            energy_b_j: 0.6,
            time_a_us: 1e4,
            time_b_us: 1e4,
            diff_frac: 1.0 / 3.0,
            wasteful: Side::A,
            is_tradeoff: false,
        }],
        wasted_j: 0.3,
        aligned: true,
        resyncs: 0,
        quarantined: false,
        content_mismatches: 0,
        window_fp: 0x00c0_ffee + seq as u64,
    }
}

fn main() {
    banner("Telemetry IO", "snapshot append/replay throughput + bounded rotation");
    let dir =
        std::env::temp_dir().join(format!("magneton-telemetry-bench-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);

    let n = 5000usize;
    let budget: u64 = 512 * 1024;
    let cfg = SinkConfig { max_snapshot_bytes: budget, rotate_bytes: 64 * 1024 };
    let snaps: Vec<Snapshot> =
        (0..n).map(|i| Snapshot::Window { pair: "bench".into(), report: window(i) }).collect();

    // --- append throughput under rotation --------------------------------
    let mut sink = SnapshotSink::new(&dir, "bench", cfg).expect("sink");
    let ((), write_us) = time_once(|| {
        for s in &snaps {
            sink.append(s).expect("append");
        }
    });
    // the rotation invariant: disk usage bounded by the budget, not by n
    assert!(
        sink.total_bytes() <= budget,
        "rotation failed: {} bytes retained > {budget} budget",
        sink.total_bytes()
    );
    assert!(sink.dropped_files > 0, "bench must exercise file drops");
    assert_eq!(sink.written, n);
    assert_eq!(sink.written_bytes, sink.total_bytes() + sink.dropped_bytes);

    // --- replay (read + parse) throughput over the retained suffix -------
    let (loaded, read_us) = time_once(|| load_dir(&dir).expect("load"));
    assert!(!loaded.is_empty() && loaded.len() < n, "retained suffix expected");
    // the retained suffix replays losslessly, ending at the last write
    assert_eq!(loaded.last().expect("non-empty").to_line(), snaps.last().expect("n > 0").to_line());

    // --- in-memory parse cost (no filesystem) -----------------------------
    let lines: Vec<String> = snaps.iter().take(1000).map(Snapshot::to_line).collect();
    let (parsed, parse_us) = time_once(|| {
        lines.iter().map(|l| Snapshot::parse_line(l).expect("parse")).count()
    });
    assert_eq!(parsed, lines.len());

    // --- follow lag: cold catch-up, then an incremental tail poll ---------
    let mut follower = Follower::new(&dir);
    let (caught, follow_cold_us) = time_once(|| follower.poll().expect("cold poll"));
    assert_eq!(
        caught.len(),
        loaded.len(),
        "cold catch-up must surface the whole retained suffix"
    );
    let extra = 500usize;
    for s in snaps.iter().take(extra) {
        sink.append(s).expect("append tail");
    }
    let (fresh, follow_incr_us) = time_once(|| follower.poll().expect("incremental poll"));
    assert_eq!(fresh.len(), extra, "an up-to-date follower sees exactly the new appends");

    let mut t = Table::new(vec!["stage", "items", "total", "per item"]);
    let mut csv = String::from("stage,items,total_us,per_item_us\n");
    let mut stages: Vec<Json> = Vec::new();
    for (stage, items, us) in [
        ("append (rotating sink)", n, write_us),
        ("replay (read+parse dir)", loaded.len(), read_us),
        ("parse (in-memory)", parsed, parse_us),
        ("follow (cold catch-up)", caught.len(), follow_cold_us),
        ("follow (incremental poll)", fresh.len(), follow_incr_us),
    ] {
        t.row(vec![
            stage.to_string(),
            items.to_string(),
            format!("{:.1} ms", us / 1e3),
            format!("{:.2} µs", us / items as f64),
        ]);
        csv.push_str(&format!("{stage},{items},{us:.1},{:.3}\n", us / items as f64));
        stages.push(
            Json::obj()
                .field("stage", stage)
                .field("items", items)
                .field("total_us", us)
                .field("per_item_us", us / items as f64)
                .build(),
        );
    }
    let rendered = t.render();
    println!("{rendered}");
    println!(
        "retained {} files / {} bytes after {} snapshots ({} files dropped) — disk bounded by budget",
        sink.retained_files(),
        sink.total_bytes(),
        n,
        sink.dropped_files
    );
    persist("telemetry_io", &rendered, Some(&csv));
    persist_json(
        "BENCH_telemetry_io",
        &Json::obj()
            .field("bench", "telemetry_io")
            .field("stages", stages)
            .field("snapshots", n)
            .field("retained_bytes", sink.total_bytes() as f64)
            .field("dropped_files", sink.dropped_files as f64)
            .field("follow_reanchors", follower.reanchors as f64)
            .build(),
    );
    let _ = std::fs::remove_dir_all(&dir);
}
