//! Table 3 replica: the 8 previously-unknown issues Magneton exposes
//! via cross-system comparison and operator fuzzing.

use magneton::cases::new_cases;
use magneton::coordinator::Magneton;
use magneton::energy::DeviceSpec;
use magneton::util::bench::{banner, persist, persist_json};
use magneton::util::json::Json;
use magneton::util::table::Table;
use magneton::util::Prng;

fn main() {
    banner("Table 3", "New issues exposed by differential comparison (paper: 8 found, 7 confirmed)");
    let mag = Magneton::new(DeviceSpec::h200_sim());
    let mut rng = Prng::new(2027);
    let mut t = Table::new(vec!["Case", "Paper cat.", "Detected", "Diff.", "Magneton diagnosis"]);
    let mut found = 0;
    for s in new_cases() {
        let (a, b) = (s.build)(&mut rng);
        let out = mag.audit(&a, &b);
        if out.detected() {
            found += 1;
        }
        let diag = out
            .diagnoses
            .first()
            .map(|(_, d)| format!("[{}] {}", d.category.name(), d.subject))
            .unwrap_or_else(|| "-".into());
        t.row(vec![
            s.id.to_string(),
            s.category.name().to_string(),
            if out.detected() { "yes".into() } else { "no".to_string() },
            format!("{:.1}%", out.e2e_diff_frac * 100.0),
            diag.chars().take(76).collect(),
        ]);
    }
    let rendered = t.render();
    println!("{rendered}");
    let summary = format!("exposed {found}/8 new issues (paper: 8 found, 7 confirmed by developers)");
    println!("{summary}");
    persist("table3_new_issues", &format!("{rendered}\n{summary}\n"), Some(&t.to_csv()));
    persist_json(
        "BENCH_table3_new_issues",
        &Json::obj().field("bench", "table3_new_issues").field("found", found as usize).build(),
    );
    assert!(found >= 7);
}
