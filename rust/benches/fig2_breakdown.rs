//! Fig 2 replica: HuggingFace total energy + top-5 operator breakdown,
//! addmm vs the add+matmul fix (case c10's workload: single-layer
//! GPT-2, large batch·seq).
//!
//! Paper shape: ~10 % less inference energy with the fix, at ~1 %
//! performance difference — invisible to a latency profiler.

use magneton::cases::by_id;
use magneton::coordinator::Magneton;
use magneton::energy::DeviceSpec;
use magneton::report::energy_breakdown;
use magneton::util::bench::{banner, persist, persist_json};
use magneton::util::json::Json;
use magneton::util::table::fmt_joules;
use magneton::util::Prng;

fn main() {
    banner("Fig 2", "HF energy breakdown: torch.addmm vs add+matmul (case c10 workload)");
    let mag = Magneton::new(DeviceSpec::h200_sim());
    let mut rng = Prng::new(2026);
    let s = by_id("c10").expect("c10 registered");
    let (a, b) = (s.build)(&mut rng);
    let ra = mag.run_side(&a);
    let rb = mag.run_side(&b);

    let mut out = String::new();
    for (label, arts) in [(&a.label, &ra), (&b.label, &rb)] {
        out.push_str(&format!(
            "\n--- {label}: total {} / wall {:.1} us ---\n",
            fmt_joules(arts.total_energy_j),
            arts.gpu_time_us
        ));
        out.push_str(&energy_breakdown(arts, 5).render());
    }
    let ediff = (ra.total_energy_j - rb.total_energy_j) / rb.total_energy_j * 100.0;
    let tdiff = (ra.gpu_time_us - rb.gpu_time_us) / rb.gpu_time_us * 100.0;
    out.push_str(&format!(
        "\naddmm consumes {ediff:+.1}% energy vs add+mm (paper: +10.0%) at {tdiff:+.1}% time (paper: ~1%)\n"
    ));
    println!("{out}");
    persist("fig2_breakdown", &out, Some(&energy_breakdown(&ra, 5).to_csv()));
    persist_json(
        "BENCH_fig2_breakdown",
        &Json::obj()
            .field("bench", "fig2_breakdown")
            .field("energy_a_j", ra.total_energy_j)
            .field("energy_b_j", rb.total_energy_j)
            .field("energy_diff_pct", ediff)
            .field("time_diff_pct", tdiff)
            .build(),
    );
    assert!(ediff > 3.0, "addmm waste not visible: {ediff:.1}%");
    // our simulated kernels are launch-light, so the extra `add` launch
    // shows up more than on the paper's H200; the shape (energy diff >>
    // time diff is NOT required for detection) still holds
    assert!(tdiff.abs() < 20.0, "fix should be roughly performance-neutral: {tdiff:.1}%");
}
