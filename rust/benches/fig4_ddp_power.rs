//! Fig 4 replica: power-vs-time of the light DDP rank under
//! `dist.Join` vs hand-written early exit (case c9's training setup).
//!
//! Paper shape: with early exit the light rank drops to idle power
//! between iterations, cutting total energy ~23 %; `dist.Join` keeps it
//! spinning near compute power. Emits the two series as CSV for
//! plotting and prints a coarse ASCII timeline.

use magneton::energy::DeviceSpec;
use magneton::util::bench::{banner, persist, persist_json};
use magneton::util::json::Json;
use magneton::workload::{run_ddp, DdpWorkload, SyncStrategy};

fn ascii_series(points: &[(f64, f64)], max_w: f64, width: usize) -> String {
    let step = points.len().max(1) / width.max(1) + 1;
    points
        .iter()
        .step_by(step)
        .map(|&(_, w)| {
            let lvl = (w / max_w * 8.0).min(8.0) as usize;
            [" ", "_", ".", ":", "-", "=", "+", "*", "#"][lvl]
        })
        .collect()
}

fn main() {
    banner("Fig 4", "DDP light-rank power: dist.Join vs early exit (uneven 1.3:1 batches)");
    let dev = DeviceSpec::h200_sim();
    let w = DdpWorkload::paper_setup();
    let join = run_ddp(&dev, &w, SyncStrategy::Join, 7);
    let exit = run_ddp(&dev, &w, SyncStrategy::EarlyExit, 7);

    // resample the light rank (rank 1) at high rate for the figure
    let hz = 1e6 / 20.0; // one point per 20 us
    let pj = join.traces[1].resample(hz);
    let pe = exit.traces[1].resample(hz);
    let mut csv = String::from("t_ms,join_w,early_exit_w\n");
    for (a, b) in pj.iter().zip(pe.iter()) {
        csv.push_str(&format!("{:.3},{:.1},{:.1}\n", a.0, a.1, b.1));
    }

    let saving = (1.0 - exit.total_energy_j / join.total_energy_j) * 100.0;
    let light_saving = (1.0 - exit.traces[1].total_energy() / join.traces[1].total_energy()) * 100.0;
    let mut out = String::new();
    out.push_str(&format!("join   : {}", ascii_series(&pj, dev.max_w * 0.6, 100)));
    out.push_str(&format!("\nearly  : {}", ascii_series(&pe, dev.max_w * 0.6, 100)));
    out.push_str(&format!(
        "\n\nlight-rank energy saving: {light_saving:.1}%   total (2-rank) saving: {saving:.1}%  (paper: ~23% overall)\n\
         wall time: join {:.2} ms vs early-exit {:.2} ms (unchanged straggler)\n",
        join.wall_us / 1e3,
        exit.wall_us / 1e3,
    ));
    println!("{out}");
    persist("fig4_ddp_power", &out, Some(&csv));
    persist_json(
        "BENCH_fig4_ddp_power",
        &Json::obj()
            .field("bench", "fig4_ddp_power")
            .field("total_saving_pct", saving)
            .field("light_rank_saving_pct", light_saving)
            .field("join_wall_us", join.wall_us)
            .field("early_exit_wall_us", exit.wall_us)
            .build(),
    );
    assert!(saving > 1.0, "early exit must save energy ({saving:.2}%)");
    assert!((join.wall_us - exit.wall_us).abs() / join.wall_us < 0.05);
}
