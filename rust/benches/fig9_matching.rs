//! Fig 9 replica: efficiency/scalability of the topology-aware matcher
//! vs the brute-force strawman.
//!
//! Paper shape: GPT-2-scale graphs (757 vs 408 nodes) match in ~167 ms
//! and Llama-8B-scale in ~1.4 s with Algorithm 1, while brute force
//! times out (5 min). We time both on growing graph sizes; brute force
//! gets a work budget equivalent to the timeout.

use std::time::Duration;

use magneton::coordinator::Magneton;
use magneton::energy::DeviceSpec;
use magneton::fingerprint::RustMomentEngine;
use magneton::matching::{brute_force_match, find_equivalent_tensors, recursive_match};
use magneton::systems::llm;
use magneton::systems::SystemId;
use magneton::util::bench::{banner, persist, persist_json, time_once};
use magneton::util::json::Json;
use magneton::util::table::{fmt_us, Table};
use magneton::util::Prng;

fn main() {
    banner("Fig 9", "Matching latency: Algorithm 1 vs brute force (paper: 167 ms / 1.4 s vs timeout)");
    let mag = Magneton::new(DeviceSpec::h200_sim());
    let mut t = Table::new(vec![
        "workload", "|G1|", "|G2|", "eq pairs", "regions", "match (Alg.1)", "brute force",
    ]);
    let mut csv = String::from("workload,n1,n2,alg1_us,brute_us\n");
    let mut rows: Vec<Json> = Vec::new();
    let mut rng = Prng::new(2026);

    // (graph-size scale, label): layers chosen so node counts bracket
    // the paper's GPT-2 (408/757) and Llama-8B scales
    for (label, layers) in [("gpt2-scale", 6), ("llama8b-scale", 22)] {
        let params = llm::TransformerParams::new(&mut rng, llm::LlmSpec::llama_sim(layers));
        let a = magneton::coordinator::SysRun::new(
            "hf",
            llm::hf_dispatcher(),
            llm::default_env(SystemId::MiniHf),
            llm::build_llm(&params, &llm::LlmBuildOpts::hf()),
        );
        let b = magneton::coordinator::SysRun::new(
            "vllm",
            llm::vllm_dispatcher(),
            llm::default_env(SystemId::MiniVllm),
            llm::build_llm(&params, &llm::LlmBuildOpts::vllm()),
        );
        let ra = mag.run_side(&a);
        let rb = mag.run_side(&b);
        let eq = find_equivalent_tensors(&ra, &rb, mag.eps, &RustMomentEngine);
        let (regions, alg1_us) = time_once(|| recursive_match(&ra.graph, &rb.graph, &eq));
        // brute-force budget: the work Algorithm 1's wall time would buy,
        // scaled to the paper's 5-minute timeout (~3e9 checks)
        let budget: u64 = 200_000_000;
        let (bf, bf_us) = time_once(|| brute_force_match(&ra.graph, &rb.graph, &eq, budget));
        let bf_str = match bf {
            Some(_) => fmt_us(bf_us),
            None => format!("TIMEOUT (> {})", fmt_us(bf_us)),
        };
        t.row(vec![
            label.to_string(),
            ra.graph.len().to_string(),
            rb.graph.len().to_string(),
            eq.len().to_string(),
            regions.len().to_string(),
            fmt_us(alg1_us),
            bf_str,
        ]);
        csv.push_str(&format!(
            "{label},{},{},{alg1_us:.0},{bf_us:.0}\n",
            ra.graph.len(),
            rb.graph.len()
        ));
        rows.push(
            Json::obj()
                .field("workload", label)
                .field("alg1_us", alg1_us)
                .field("brute_force_us", bf_us)
                .field("brute_force_timed_out", bf.is_none())
                .build(),
        );
        if label == "llama8b-scale" {
            assert!(bf.is_none(), "brute force should exhaust its budget at Llama scale");
            assert!(
                Duration::from_micros(alg1_us as u64) < Duration::from_secs(10),
                "Algorithm 1 too slow: {}",
                fmt_us(alg1_us)
            );
        }
    }
    let rendered = t.render();
    println!("{rendered}");
    persist("fig9_matching", &rendered, Some(&csv));
    persist_json(
        "BENCH_fig9_matching",
        &Json::obj().field("bench", "fig9_matching").field("workloads", rows).build(),
    );
}
