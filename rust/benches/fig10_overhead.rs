//! Fig 10 replica: runtime overhead of Magneton's tracing modules on
//! HF-Transformers and vLLM serving a mixed workload.
//!
//! Paper shape: 4.4 % (HF) and 5.9 % (vLLM) end-to-end overhead with
//! tracing enabled.

use magneton::coordinator::Magneton;
use magneton::energy::DeviceSpec;
use magneton::systems::llm;
use magneton::systems::SystemId;
use magneton::util::bench::{banner, persist, persist_json};
use magneton::util::json::Json;
use magneton::util::table::Table;
use magneton::util::Prng;

fn main() {
    banner("Fig 10", "Tracing overhead on HF & vLLM (paper: 4.4% / 5.9%)");
    let mut rng = Prng::new(2026);
    // mixed workload: 1 prefill (128 tokens) + many decode-ish tokens —
    // approximated by the gpt2_sim prefill graph
    let params = llm::TransformerParams::new(&mut rng, llm::LlmSpec::gpt2_sim());

    let mut t = Table::new(vec!["system", "untraced wall", "traced wall", "overhead"]);
    let mut csv = String::from("system,overhead_pct\n");
    let mut rows: Vec<Json> = Vec::new();
    for (name, opts, disp, env) in [
        ("mini-hf-transformers", llm::LlmBuildOpts::hf(), llm::hf_dispatcher(), llm::default_env(SystemId::MiniHf)),
        ("mini-vllm", llm::LlmBuildOpts::vllm(), llm::vllm_dispatcher(), llm::default_env(SystemId::MiniVllm)),
    ] {
        let run = magneton::coordinator::SysRun::new(name, disp, env, llm::build_llm(&params, &opts));
        let mut mag = Magneton::new(DeviceSpec::h200_sim());
        mag.exec_opts.tracing = false;
        let off = mag.run_side(&run);
        mag.exec_opts.tracing = true;
        let on = mag.run_side(&run);
        let overhead = (on.wall_time_us - off.wall_time_us) / off.wall_time_us * 100.0;
        t.row(vec![
            name.to_string(),
            format!("{:.1} us", off.wall_time_us),
            format!("{:.1} us", on.wall_time_us),
            format!("{overhead:.1}%"),
        ]);
        csv.push_str(&format!("{name},{overhead:.2}\n"));
        rows.push(Json::obj().field("system", name).field("overhead_pct", overhead).build());
        assert!(overhead > 0.5 && overhead < 12.0, "{name} overhead out of band: {overhead:.1}%");
    }
    let rendered = t.render();
    println!("{rendered}");
    println!("(paper: 4.4% HF, 5.9% vLLM; offline diagnosis completes within minutes)");
    persist("fig10_overhead", &rendered, Some(&csv));
    persist_json(
        "BENCH_fig10_overhead",
        &Json::obj().field("bench", "fig10_overhead").field("systems", rows).build(),
    );
}
