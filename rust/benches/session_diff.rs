//! Cross-session diff bench: persist two sessions of the same serving
//! workload (one with an injected per-label regression), then measure
//! session load, matching, and the full differential replay — plus the
//! window re-anchoring cost on long fingerprint sequences with
//! scattered skips (the alignment must stay near-linear, not
//! quadratic, when sessions drift).

use std::path::PathBuf;

use magneton::energy::Segment;
use magneton::exec::KernelRecord;
use magneton::fingerprint::WorkloadSig;
use magneton::graph::OpKind;
use magneton::report::render_session_diff;
use magneton::stream::{StreamAuditor, StreamConfig};
use magneton::telemetry::session::{align_windows, diff_sessions, DiffConfig, SessionInfo};
use magneton::telemetry::{SessionHeader, SinkConfig, SnapshotSink};
use magneton::trace::Frame;
// `self` import: the helper below shadows `bench::persist`, so the
// result emitters are called qualified
use magneton::util::bench::{self as bench, banner, time_once};
use magneton::util::json::Json;
use magneton::util::table::{fmt_us, Table};
use magneton::util::Prng;

fn cycle_op(i: usize) -> (&'static str, OpKind, f64) {
    match i % 5 {
        0 => ("serve.proj", OpKind::MatMul, 0.30),
        1 => ("serve.scale", OpKind::Mul, 0.02),
        2 => ("serve.act", OpKind::Gelu, 0.05),
        3 => ("serve.out", OpKind::MatMul, 0.30),
        _ => ("serve.softmax", OpKind::Softmax, 0.08),
    }
}

fn rec(label: &str, op: OpKind, energy_j: f64, time_us: f64) -> KernelRecord {
    KernelRecord {
        node: 0,
        op,
        label: label.to_string(),
        api: "api".into(),
        dispatch_key: op.name().to_string(),
        kernel: format!("k_{label}"),
        time_us,
        energy_j,
        avg_power_w: energy_j / (time_us * 1e-6),
        corr_id: 0,
        bb_trace: vec![],
        call_path: vec![Frame::py("serve")],
        moments: vec![],
    }
}

/// Persist one `n`-op session; `proj_scale` inflates side A's
/// `serve.proj` energy (the injected regression).
fn persist(dir: &PathBuf, id: &str, n: usize, proj_scale: f64) {
    let _ = std::fs::remove_dir_all(dir);
    let cfg = StreamConfig { window_ops: 100, hop_ops: 100, ring_cap: 128, nvml: None, ..Default::default() };
    let mut aud = StreamAuditor::new(cfg.clone(), 90.0);
    // header + sink BEFORE ingestion: windows persist at emission time
    let mut sig = WorkloadSig::new();
    for i in 0..n {
        let (label, op, _) = cycle_op(i);
        sig.add(label, op.name());
    }
    aud.set_session_header(SessionHeader::new(id, "bench", "pair", &sig, "steady", cfg.digest()));
    aud.set_sink("pair", SnapshotSink::new(dir.clone(), "pair", SinkConfig::default()).expect("sink"));
    let (mut ta, mut tb) = (0.0, 0.0);
    for i in 0..n {
        let (label, op, e) = cycle_op(i);
        let ea = if label == "serve.proj" { e * proj_scale } else { e };
        aud.ingest_a(&rec(label, op, ea, 100.0), Segment { t_start_us: ta, t_end_us: ta + 100.0, watts: ea / 100e-6 });
        ta += 100.0;
        aud.ingest_b(&rec(label, op, e, 100.0), Segment { t_start_us: tb, t_end_us: tb + 100.0, watts: e / 100e-6 });
        tb += 100.0;
        aud.take_emitted();
    }
    aud.finish();
    assert_eq!(aud.sink_errors(), 0);
}

fn main() {
    banner("Session diff", "cross-session load + match + differential replay");
    let base = std::env::temp_dir().join(format!("magneton-session-bench-{}", std::process::id()));
    let dir_a = base.join("a");
    let dir_b = base.join("b");

    let n = 20_000usize;
    let (_, build_us) = time_once(|| {
        persist(&dir_a, "deploy-a", n, 1.0);
        persist(&dir_b, "deploy-b", n, 1.3);
    });

    let ((a, b), load_us) = time_once(|| {
        (SessionInfo::load(&dir_a).expect("load a"), SessionInfo::load(&dir_b).expect("load b"))
    });
    let (diff, diff_us) = time_once(|| diff_sessions(&a, &b, &DiffConfig::default()).expect("diff"));
    assert_eq!(diff.labels[0].label, "serve.proj", "regression must rank first");
    assert!(diff.regressed(0.05));
    assert_eq!(diff.windows.aligned, n / 100);
    // deterministic: the rendered report reproduces bit-for-bit
    let (r1, render_us) = time_once(|| render_session_diff(&diff));
    let diff2 = diff_sessions(&a, &b, &DiffConfig::default()).expect("diff2");
    assert_eq!(render_session_diff(&diff2), r1, "diff must be reproducible");

    // --- window re-anchoring on long drifting sequences ------------------
    // 100k windows with 200 scattered single-window skips on each side:
    // the minimal-skip search must stay near-linear overall
    let mut rng = Prng::new(7);
    let wa: Vec<u64> = (0..100_000u64).map(|i| i * 2654435761 % 1_000_003).collect();
    let mut wb = wa.clone();
    for _ in 0..200 {
        let at = rng.below(wb.len());
        wb.remove(at);
    }
    let (al, align_us) = time_once(|| align_windows(&wa, &wb, 16));
    assert!(al.aligned > 99_000, "aligned {}", al.aligned);
    assert!(al.skipped_a >= 200);

    let mut t = Table::new(vec!["stage", "items", "total"]);
    for (stage, items, us) in [
        ("persist 2 sessions", 2 * n, build_us),
        ("load sessions", 2 * n / 100 + 2, load_us),
        ("diff (match+align+delta)", n / 100, diff_us),
        ("render report", diff.labels.len(), render_us),
        ("align 100k windows, 200 skips", 100_000, align_us),
    ] {
        t.row(vec![stage.to_string(), items.to_string(), fmt_us(us)]);
    }
    let rendered = t.render();
    print!("{rendered}");
    bench::persist("session_diff", &rendered, None);
    bench::persist_json(
        "BENCH_session_diff",
        &Json::obj()
            .field("bench", "session_diff")
            .field("n", n)
            .field("persist_us", build_us)
            .field("load_us", load_us)
            .field("diff_us", diff_us)
            .field("render_us", render_us)
            .field("align_us", align_us)
            .build(),
    );

    let _ = std::fs::remove_dir_all(&base);
}
