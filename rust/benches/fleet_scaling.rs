//! Fleet-scaling bench: (1) candidate-index equivalent-tensor matching
//! vs the all-pairs scan on growing graph sizes, and (2) a concurrent
//! `FleetAudit` of many system pairs over the bounded worker pool.
//!
//! The indexed path buckets fingerprints on `(numel, quantized
//! Frobenius band)` so each query touches a small candidate set; both
//! paths must return identical EqSets (also enforced by a property
//! test in `matching::tests`), and on graphs ≥ 200 nodes the index
//! must beat the all-pairs wall time.

use magneton::cases;
use magneton::coordinator::fleet::FleetAudit;
use magneton::coordinator::Magneton;
use magneton::energy::DeviceSpec;
use magneton::fingerprint::RustMomentEngine;
use magneton::matching::{fingerprint_run, pairs_from_fingerprints, MatchOptions};
use magneton::report;
use magneton::systems::llm;
use magneton::systems::SystemId;
use magneton::util::bench::{banner, persist, persist_json, time_once};
use magneton::util::json::Json;
use magneton::util::pool;
use magneton::util::table::{fmt_us, Table};
use magneton::util::Prng;

/// Best-of-3 wall time of one pair-discovery strategy, µs.
fn best_of_3(
    fa: &[Option<magneton::fingerprint::Fingerprint>],
    fb: &[Option<magneton::fingerprint::Fingerprint>],
    eps: f64,
    opts: MatchOptions,
) -> (magneton::matching::EqSet, f64) {
    let mut best = f64::INFINITY;
    let mut eq = None;
    for _ in 0..3 {
        let (e, us) = time_once(|| pairs_from_fingerprints(fa, fb, eps, opts));
        best = best.min(us);
        eq = Some(e);
    }
    (eq.unwrap(), best)
}

fn main() {
    banner(
        "Fleet scaling",
        "Indexed vs all-pairs tensor matching + concurrent FleetAudit over a bounded pool",
    );
    let mag = Magneton::new(DeviceSpec::h200_sim());
    let mut rng = Prng::new(2026);

    // --- part 1: matching scalability -----------------------------------
    let mut t = Table::new(vec![
        "workload", "|G1|", "|G2|", "eq pairs", "all-pairs", "indexed", "speedup",
    ]);
    let mut csv = String::from("workload,n1,n2,exhaustive_us,indexed_us\n");
    let mut rows: Vec<Json> = Vec::new();
    for (label, layers) in [("small", 2usize), ("gpt2-scale", 6), ("llama8b-scale", 14)] {
        let params = llm::TransformerParams::new(&mut rng, llm::LlmSpec::llama_sim(layers));
        let a = magneton::coordinator::SysRun::new(
            "hf",
            llm::hf_dispatcher(),
            llm::default_env(SystemId::MiniHf),
            llm::build_llm(&params, &llm::LlmBuildOpts::hf()),
        );
        let b = magneton::coordinator::SysRun::new(
            "vllm",
            llm::vllm_dispatcher(),
            llm::default_env(SystemId::MiniVllm),
            llm::build_llm(&params, &llm::LlmBuildOpts::vllm()),
        );
        let ra = mag.run_side(&a);
        let rb = mag.run_side(&b);
        let threads = pool::default_threads();
        let fa = fingerprint_run(&ra, &RustMomentEngine, threads);
        let fb = fingerprint_run(&rb, &RustMomentEngine, threads);

        let (eq_slow, slow_us) =
            best_of_3(&fa, &fb, mag.eps, MatchOptions { exhaustive: true });
        let (eq_fast, fast_us) =
            best_of_3(&fa, &fb, mag.eps, MatchOptions { exhaustive: false });
        assert_eq!(eq_slow, eq_fast, "{label}: indexed EqSet diverges from exhaustive");

        let n1 = ra.graph.len();
        let n2 = rb.graph.len();
        if n1.min(n2) >= 200 {
            assert!(
                fast_us < slow_us,
                "{label}: indexed ({}) not faster than all-pairs ({}) on {}x{} nodes",
                fmt_us(fast_us),
                fmt_us(slow_us),
                n1,
                n2
            );
        }
        t.row(vec![
            label.to_string(),
            n1.to_string(),
            n2.to_string(),
            eq_fast.len().to_string(),
            fmt_us(slow_us),
            fmt_us(fast_us),
            format!("{:.1}x", slow_us / fast_us.max(1e-9)),
        ]);
        csv.push_str(&format!("{label},{n1},{n2},{slow_us:.0},{fast_us:.0}\n"));
        rows.push(
            Json::obj()
                .field("workload", label)
                .field("n1", n1)
                .field("n2", n2)
                .field("exhaustive_us", slow_us)
                .field("indexed_us", fast_us)
                .build(),
        );
    }
    let part1 = t.render();
    println!("{part1}");

    // --- part 2: fleet audit over the evaluation suite -------------------
    let mut fleet = FleetAudit::new(DeviceSpec::h200_sim());
    let mut fleet_rng = Prng::new(2027);
    let scenarios: Vec<cases::Scenario> =
        cases::known_cases().into_iter().take(8).collect();
    assert!(scenarios.len() >= 8, "need at least 8 pairs for the fleet bench");
    for s in &scenarios {
        let (a, b) = (s.build)(&mut fleet_rng);
        fleet.add_pair(s.id, a, b);
    }
    let (fleet_report, fleet_us) = time_once(|| fleet.run());

    // aggregation invariants: totals equal per-entry sums
    assert_eq!(fleet_report.entries.len(), 8);
    let findings_sum: usize = fleet_report.entries.iter().map(|e| e.findings).sum();
    assert_eq!(fleet_report.total_findings, findings_sum);
    let waste_sum: f64 = fleet_report.entries.iter().map(|e| e.wasted_j).sum();
    assert!((fleet_report.total_wasted_j - waste_sum).abs() < 1e-9);
    assert!(fleet_report.flagged() > 0, "evaluation suite should flag waste");

    let part2 = report::render_fleet(&fleet_report);
    println!("{part2}");
    println!("fleet wall time: {} over {} workers", fmt_us(fleet_us), fleet_report.workers);

    persist("fleet_scaling", &format!("{part1}\n{part2}"), Some(&csv));
    persist_json(
        "BENCH_fleet_scaling",
        &Json::obj()
            .field("bench", "fleet_scaling")
            .field("matching", rows)
            .field("fleet_us", fleet_us)
            .field("workers", fleet_report.workers)
            .field("total_wasted_j", fleet_report.total_wasted_j)
            .field("total_findings", fleet_report.total_findings)
            .build(),
    );
}
