//! Fleet-scaling bench: (1) candidate-index equivalent-tensor matching
//! vs the all-pairs scan on growing graph sizes, (2) a concurrent
//! `FleetAudit` of many system pairs over the bounded worker pool, and
//! (3) sharded multi-process ingest — `merge_shards` wall time vs
//! shard count, gated on the merged ranking being bit-identical to the
//! single-process run.
//!
//! The indexed path buckets fingerprints on `(numel, quantized
//! Frobenius band)` so each query touches a small candidate set; both
//! paths must return identical EqSets (also enforced by a property
//! test in `matching::tests`), and on graphs ≥ 200 nodes the index
//! must beat the all-pairs wall time.

use std::path::{Path, PathBuf};

use magneton::cases;
use magneton::coordinator::fleet::{FleetAudit, StreamFleet};
use magneton::coordinator::{Magneton, SysRun};
use magneton::dispatch::Env;
use magneton::energy::DeviceSpec;
use magneton::fingerprint::RustMomentEngine;
use magneton::matching::{fingerprint_run, pairs_from_fingerprints, MatchOptions};
use magneton::report;
use magneton::systems::llm;
use magneton::systems::SystemId;
use magneton::telemetry::merge::{merge_shards, MergeConfig};
use magneton::telemetry::{Replay, SinkConfig};
use magneton::util::bench::{banner, persist, persist_json, time_once};
use magneton::util::json::Json;
use magneton::util::pool;
use magneton::util::table::{fmt_us, Table};
use magneton::util::Prng;
use magneton::workload::{serving_dispatcher, serving_stream_program, ServingStream};

/// Best-of-3 wall time of one pair-discovery strategy, µs.
fn best_of_3(
    fa: &[Option<magneton::fingerprint::Fingerprint>],
    fb: &[Option<magneton::fingerprint::Fingerprint>],
    eps: f64,
    opts: MatchOptions,
) -> (magneton::matching::EqSet, f64) {
    let mut best = f64::INFINITY;
    let mut eq = None;
    for _ in 0..3 {
        let (e, us) = time_once(|| pairs_from_fingerprints(fa, fb, eps, opts));
        best = best.min(us);
        eq = Some(e);
    }
    (eq.unwrap(), best)
}

fn main() {
    banner(
        "Fleet scaling",
        "Indexed vs all-pairs tensor matching + concurrent FleetAudit over a bounded pool",
    );
    let mag = Magneton::new(DeviceSpec::h200_sim());
    let mut rng = Prng::new(2026);

    // --- part 1: matching scalability -----------------------------------
    let mut t = Table::new(vec![
        "workload", "|G1|", "|G2|", "eq pairs", "all-pairs", "indexed", "speedup",
    ]);
    let mut csv = String::from("workload,n1,n2,exhaustive_us,indexed_us\n");
    let mut rows: Vec<Json> = Vec::new();
    for (label, layers) in [("small", 2usize), ("gpt2-scale", 6), ("llama8b-scale", 14)] {
        let params = llm::TransformerParams::new(&mut rng, llm::LlmSpec::llama_sim(layers));
        let a = magneton::coordinator::SysRun::new(
            "hf",
            llm::hf_dispatcher(),
            llm::default_env(SystemId::MiniHf),
            llm::build_llm(&params, &llm::LlmBuildOpts::hf()),
        );
        let b = magneton::coordinator::SysRun::new(
            "vllm",
            llm::vllm_dispatcher(),
            llm::default_env(SystemId::MiniVllm),
            llm::build_llm(&params, &llm::LlmBuildOpts::vllm()),
        );
        let ra = mag.run_side(&a);
        let rb = mag.run_side(&b);
        let threads = pool::default_threads();
        let fa = fingerprint_run(&ra, &RustMomentEngine, threads);
        let fb = fingerprint_run(&rb, &RustMomentEngine, threads);

        let (eq_slow, slow_us) =
            best_of_3(&fa, &fb, mag.eps, MatchOptions { exhaustive: true });
        let (eq_fast, fast_us) =
            best_of_3(&fa, &fb, mag.eps, MatchOptions { exhaustive: false });
        assert_eq!(eq_slow, eq_fast, "{label}: indexed EqSet diverges from exhaustive");

        let n1 = ra.graph.len();
        let n2 = rb.graph.len();
        if n1.min(n2) >= 200 {
            assert!(
                fast_us < slow_us,
                "{label}: indexed ({}) not faster than all-pairs ({}) on {}x{} nodes",
                fmt_us(fast_us),
                fmt_us(slow_us),
                n1,
                n2
            );
        }
        t.row(vec![
            label.to_string(),
            n1.to_string(),
            n2.to_string(),
            eq_fast.len().to_string(),
            fmt_us(slow_us),
            fmt_us(fast_us),
            format!("{:.1}x", slow_us / fast_us.max(1e-9)),
        ]);
        csv.push_str(&format!("{label},{n1},{n2},{slow_us:.0},{fast_us:.0}\n"));
        rows.push(
            Json::obj()
                .field("workload", label)
                .field("n1", n1)
                .field("n2", n2)
                .field("exhaustive_us", slow_us)
                .field("indexed_us", fast_us)
                .build(),
        );
    }
    let part1 = t.render();
    println!("{part1}");

    // --- part 2: fleet audit over the evaluation suite -------------------
    let mut fleet = FleetAudit::new(DeviceSpec::h200_sim());
    let mut fleet_rng = Prng::new(2027);
    let scenarios: Vec<cases::Scenario> =
        cases::known_cases().into_iter().take(8).collect();
    assert!(scenarios.len() >= 8, "need at least 8 pairs for the fleet bench");
    for s in &scenarios {
        let (a, b) = (s.build)(&mut fleet_rng);
        fleet.add_pair(s.id, a, b);
    }
    let (fleet_report, fleet_us) = time_once(|| fleet.run());

    // aggregation invariants: totals equal per-entry sums
    assert_eq!(fleet_report.entries.len(), 8);
    let findings_sum: usize = fleet_report.entries.iter().map(|e| e.findings).sum();
    assert_eq!(fleet_report.total_findings, findings_sum);
    let waste_sum: f64 = fleet_report.entries.iter().map(|e| e.wasted_j).sum();
    assert!((fleet_report.total_wasted_j - waste_sum).abs() < 1e-9);
    assert!(fleet_report.flagged() > 0, "evaluation suite should flag waste");

    let part2 = report::render_fleet(&fleet_report);
    println!("{part2}");
    println!("fleet wall time: {} over {} workers", fmt_us(fleet_us), fleet_report.workers);

    // --- part 3: sharded ingest merge vs shard count ---------------------
    // One 8-pair streaming fleet persisted unsharded (the reference),
    // then re-produced as 1/2/4/8 producer shards and merged. The merge
    // is only worth timing if it is *correct*: every row asserts the
    // merged ranking reproduces the single-process ranking bit-for-bit.
    let base =
        std::env::temp_dir().join(format!("magneton-bench-merge-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&base);
    let total_pairs = 8usize;
    let requests = 20usize;
    let unsharded = base.join("unsharded");
    shard_slice(&unsharded, 0, total_pairs, None, requests);
    let reference = Replay::load(&unsharded).expect("unsharded replay");
    let ref_ranking = reference.rankings.last().expect("persisted ranking");

    let mut t3 = Table::new(vec!["shards", "snapshots", "merge", "bit-identical"]);
    let mut merge_rows: Vec<Json> = Vec::new();
    for count in [1usize, 2, 4, 8] {
        let per_shard = total_pairs.div_ceil(count);
        let dirs: Vec<PathBuf> = (0..count)
            .map(|idx| {
                let dir = base.join(format!("m{count}-s{idx}"));
                let (lo, hi) =
                    ((idx * per_shard).min(total_pairs), ((idx + 1) * per_shard).min(total_pairs));
                shard_slice(&dir, lo, hi, Some((idx, count)), requests);
                dir
            })
            .collect();
        let cfg = MergeConfig { correlate_window_ops: 40, correlate_min: 2, allow_partial: false };
        let mut best = f64::INFINITY;
        let mut merged = None;
        for _ in 0..3 {
            let (m, us) = time_once(|| merge_shards(&dirs, &cfg).expect("merge"));
            best = best.min(us);
            merged = Some(m);
        }
        let merged = merged.unwrap();
        assert_eq!(merged.ranking.len(), ref_ranking.len(), "{count} shards");
        for (got, want) in merged.ranking.iter().zip(ref_ranking.iter()) {
            assert_eq!(got.name, want.name, "{count} shards");
            assert_eq!(
                got.wasted_j.to_bits(),
                want.wasted_j.to_bits(),
                "{count} shards: {} not bit-identical to the single-process run",
                got.name
            );
        }
        let snapshots: usize = merged.shards.iter().map(|s| s.snapshots).sum();
        t3.row(vec![
            count.to_string(),
            snapshots.to_string(),
            fmt_us(best),
            "yes".to_string(),
        ]);
        merge_rows.push(
            Json::obj()
                .field("shards", count)
                .field("snapshots", snapshots)
                .field("merge_us", best)
                .field("pairs", total_pairs)
                .build(),
        );
    }
    let part3 = t3.render();
    println!("{part3}");
    let _ = std::fs::remove_dir_all(&base);

    persist("fleet_scaling", &format!("{part1}\n{part2}\n{part3}"), Some(&csv));
    persist_json(
        "BENCH_fleet_scaling",
        &Json::obj()
            .field("bench", "fleet_scaling")
            .field("matching", rows)
            .field("fleet_us", fleet_us)
            .field("workers", fleet_report.workers)
            .field("total_wasted_j", fleet_report.total_wasted_j)
            .field("total_findings", fleet_report.total_findings)
            .field("merge", merge_rows)
            .build(),
    );
}

/// Persist the fleet slice `[lo, hi)` of an 8-pair serving fleet into
/// `dir` — unsharded reference (`shard: None`) or one producer shard,
/// mirroring `magneton stream --shard` (fleet-global pair indices and
/// seeds, never-rotating sinks).
fn shard_slice(dir: &Path, lo: usize, hi: usize, shard: Option<(usize, usize)>, requests: usize) {
    let seed = 0xbe2c;
    let mut fleet = StreamFleet::new(DeviceSpec::h200_sim());
    fleet.workers = 2;
    fleet.cfg.window_ops = 40;
    fleet.cfg.hop_ops = 40;
    fleet.cfg.ring_cap = 64;
    fleet.snapshot_dir = Some(dir.to_path_buf());
    fleet.session_id = Some("bench-merge".to_string());
    fleet.deploy_tag = "bench".into();
    fleet.sink_cfg = SinkConfig { max_snapshot_bytes: 0, rotate_bytes: 0 };
    if let Some((idx, count)) = shard {
        fleet.pair_index_base = lo;
        fleet.shard_id = format!("host-{idx}");
        fleet.shard_index = idx;
        fleet.shard_count = count;
    }
    let spec = ServingStream { requests, batch: 64, d_model: 128 };
    for i in lo..hi {
        let eff = if i % 2 == 0 { 0.6 } else { 1.0 };
        let mut ra = Prng::new(seed + 1 + i as u64);
        let mut rb = Prng::new(seed + 1 + i as u64);
        fleet.add_pair(
            &format!("serving-{i}"),
            SysRun::new("sys-a", serving_dispatcher(eff), Env::new(), serving_stream_program(&mut ra, &spec)),
            SysRun::new("sys-b", serving_dispatcher(1.0), Env::new(), serving_stream_program(&mut rb, &spec)),
        );
    }
    let r = fleet.run();
    assert_eq!(r.snapshot_errors, 0, "bench shard snapshot writes must succeed");
}
