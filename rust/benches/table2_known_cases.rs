//! Table 2 replica: detection + diagnosis of the 16 known cases, with
//! the baselines' ranks (PyTorch profiler latency rank; Zeus; Zeus-replay).
//!
//! Paper shape to reproduce: Magneton diagnoses 15/16 (c11 missed —
//! CPU-side); PyTorch profiler ranks only a few cases in its top-3;
//! Zeus cannot measure microsecond kernels; Zeus-replay ranks several
//! cases top-5 but offers no root cause.

use std::time::Duration;

use magneton::cases::known_cases;
use magneton::coordinator::Magneton;
use magneton::detect::Side;
use magneton::energy::DeviceSpec;
use magneton::profiler::{pytorch_profiler, rank_of, zeus, zeus_replay};
use magneton::util::bench::{banner, persist, persist_json, time_once};
use magneton::util::json::Json;
use magneton::util::table::Table;
use magneton::util::Prng;

fn main() {
    banner(
        "Table 2",
        "Known-case detection/diagnosis + baseline ranks (paper: 15/16 diagnosed, avg diff 13.6%)",
    );
    let mag = Magneton::new(DeviceSpec::h200_sim());
    let mut rng = Prng::new(2026);
    let mut table = Table::new(vec![
        "Id", "Case", "Magneton Diag.", "Diff.", "PyTorch rank", "Zeus rank", "Zeus-replay rank", "Category",
    ]);
    let mut diagnosed = 0;
    let mut detectable = 0;
    let mut diffs = Vec::new();
    let (_, total_us) = time_once(|| {
        for s in known_cases() {
            let (a, b) = (s.build)(&mut rng);
            let out = mag.audit(&a, &b);
            let diag_ok = out.detected()
                && out.diagnoses.iter().any(|(f, d)| {
                    s.expect.is_empty()
                        || d.render().to_lowercase().contains(&s.expect.to_lowercase())
                        || f.labels.iter().any(|l| l.to_lowercase().contains(&s.expect.to_lowercase()))
                });
            if !s.expect_undetected {
                detectable += 1;
                if diag_ok {
                    diagnosed += 1;
                    diffs.push(out.e2e_diff_frac * 100.0);
                }
            }
            // baselines run on the wasteful side's artifacts
            let waste = match out.findings.first().map(|f| f.wasteful) {
                Some(Side::B) => &out.b,
                _ => &out.a,
            };
            let needle = if s.expect.is_empty() { "\u{0}" } else { s.expect };
            let pt = rank_of(&pytorch_profiler(waste), needle);
            let zs = rank_of(&zeus(waste), needle);
            let zr = rank_of(&zeus_replay(waste, 1000), needle);
            let fmt_rank = |r: Option<usize>| match r {
                Some(n) if n <= 100 => format!("{n}"),
                Some(_) => ">100".to_string(),
                None => "-".to_string(),
            };
            let cat = out
                .diagnoses
                .first()
                .map(|(_, d)| d.category.name().to_string())
                .unwrap_or_else(|| "-".into());
            table.row(vec![
                s.id.to_string(),
                s.issue.to_string(),
                if s.expect_undetected {
                    if out.detected() { "detected(!)".into() } else { "x (by design)".to_string() }
                } else if diag_ok {
                    "ok".into()
                } else {
                    "MISS".into()
                },
                format!("{:.1}%", out.e2e_diff_frac * 100.0),
                fmt_rank(pt),
                fmt_rank(zs),
                fmt_rank(zr),
                cat,
            ]);
        }
    });
    let rendered = table.render();
    println!("{rendered}");
    let avg = if diffs.is_empty() { 0.0 } else { diffs.iter().sum::<f64>() / diffs.len() as f64 };
    let summary = format!(
        "diagnosed {diagnosed}/{detectable} detectable cases (paper: 15/15 + c11 missed by design)\n\
         average end-to-end energy diff of diagnosed cases: {avg:.1}% (paper: 13.6%)\n\
         total wall time: {:?}",
        Duration::from_micros(total_us as u64)
    );
    println!("{summary}");
    persist("table2_known_cases", &format!("{rendered}\n{summary}\n"), Some(&table.to_csv()));
    persist_json(
        "BENCH_table2_known_cases",
        &Json::obj()
            .field("bench", "table2_known_cases")
            .field("diagnosed", diagnosed as usize)
            .field("detectable", detectable as usize)
            .field("avg_diff_pct", avg)
            .field("total_us", total_us)
            .build(),
    );
    assert!(diagnosed >= detectable - 1, "regression: too many missed cases");
}
