//! Fig 5 replica: cross-system energy comparison.
//!
//! (b) J/token for LLM serving (vLLM vs SGLang vs HF) on two request
//!     mixes — paper: HF up to 2.97× SGLang;
//! (c) convolution energy (PyTorch vs TF vs JAX) — paper: up to 3.35×;
//! (d) image-generation energy per patch (SD vs Diffusers).

use magneton::dispatch::Env;
use magneton::energy::DeviceSpec;
use magneton::exec::Executor;
use magneton::systems::frameworks as fw;
use magneton::systems::imagegen as ig;
use magneton::systems::llm;
use magneton::systems::SystemId;
use magneton::util::bench::{banner, persist, persist_json};
use magneton::util::json::Json;
use magneton::util::table::Table;
use magneton::util::Prng;
use magneton::workload::{fig5b_mixes, serve_mix};

fn main() {
    banner("Fig 5", "Energy comparison across functionally-equivalent systems");
    let dev = DeviceSpec::h200_sim();
    let mut rng = Prng::new(2026);
    let mut csv = String::from("panel,system,workload,value\n");

    // ---- (b) LLM serving J/token ---------------------------------
    let params = llm::TransformerParams::new(&mut rng, llm::LlmSpec::gpt2_sim());
    let mut tb = Table::new(vec!["system", "mix (in,out)", "J/token (sim)"]);
    let mut jt: Vec<(String, f64)> = Vec::new();
    for mix in fig5b_mixes() {
        for (name, opts, disp, env) in [
            ("mini-vllm", llm::LlmBuildOpts::vllm(), llm::vllm_dispatcher(), llm::default_env(SystemId::MiniVllm)),
            ("mini-sglang", llm::LlmBuildOpts::sglang(), llm::sglang_dispatcher(), llm::default_env(SystemId::MiniSglang)),
            ("mini-hf", llm::LlmBuildOpts::hf(), llm::hf_dispatcher(), llm::default_env(SystemId::MiniHf)),
        ] {
            let exec = Executor::new(dev.clone(), disp, env);
            let (e, _t) = serve_mix(&exec, &params, &opts, &mix);
            let per_tok = e / mix.total_tokens() as f64;
            tb.row(vec![
                name.to_string(),
                format!("({},{})", mix.input_tokens, mix.output_tokens),
                format!("{:.3e}", per_tok),
            ]);
            csv.push_str(&format!("5b,{name},({},{}),{per_tok:.6e}\n", mix.input_tokens, mix.output_tokens));
            jt.push((name.to_string(), per_tok));
        }
    }
    println!("(b) LLM serving energy per token\n{}", tb.render());
    let hf = jt.iter().filter(|(n, _)| n == "mini-hf").map(|(_, v)| *v).fold(0.0, f64::max);
    let sg = jt.iter().filter(|(n, _)| n == "mini-sglang").map(|(_, v)| *v).fold(f64::MAX, f64::min);
    let ratio_b = hf / sg;
    println!("max HF / min SGLang ratio: {ratio_b:.2}x (paper: up to 2.97x)\n");

    // ---- (c) convolution energy -----------------------------------
    let spec = fw::ConvSpec::fig5c();
    let (x, w) = fw::conv_params(&mut rng, spec);
    let mut tc = Table::new(vec!["framework", "conv energy (J)"]);
    let mut conv_e = Vec::new();
    for (name, prog, disp, env) in [
        ("mini-pytorch", fw::build_conv("torch", spec, fw::ConvLayout::Nchw, &x, &w, "torch.conv2d"), fw::torch_dispatcher(), Env::new()),
        ("mini-tensorflow", fw::build_conv("tf", spec, fw::ConvLayout::Nchw, &x, &w, "tf.conv2d"), fw::tf_dispatcher(), Env::new()),
        ("mini-jax", fw::build_conv("jax", spec, fw::ConvLayout::Nchw, &x, &w, "jax.conv2d"), fw::jax_dispatcher(), Env::new().with("groups", "1")),
    ] {
        let arts = Executor::new(dev.clone(), disp, env).run(&prog);
        tc.row(vec![name.to_string(), format!("{:.3e}", arts.total_energy_j)]);
        csv.push_str(&format!("5c,{name},conv,{:.6e}\n", arts.total_energy_j));
        conv_e.push(arts.total_energy_j);
    }
    println!("(c) convolution operator energy\n{}", tc.render());
    let ratio_c = conv_e.iter().cloned().fold(0.0, f64::max) / conv_e.iter().cloned().fold(f64::MAX, f64::min);
    println!("max/min conv ratio: {ratio_c:.2}x (paper: up to 3.35x)\n");

    // ---- (d) image generation energy per patch ---------------------
    let uparams = ig::UnetParams::new(&mut rng, ig::UnetSpec::sd3_sim());
    let patches = (uparams.spec.batch * uparams.spec.hw * uparams.spec.hw) as f64;
    let mut td = Table::new(vec!["system", "energy/patch (J)"]);
    let mut img_e = Vec::new();
    for (name, opts, disp, env) in [
        ("mini-stable-diffusion", ig::UnetBuildOpts::sd(), ig::sd_dispatcher(), ig::sd_env(false)),
        ("mini-diffusers", ig::UnetBuildOpts::diffusers(), ig::diffusers_dispatcher(), ig::sd_env(true)),
    ] {
        let arts = Executor::new(dev.clone(), disp, env).run(&ig::build_unet_block(&uparams, &opts));
        td.row(vec![name.to_string(), format!("{:.3e}", arts.total_energy_j / patches)]);
        csv.push_str(&format!("5d,{name},unet,{:.6e}\n", arts.total_energy_j / patches));
        img_e.push(arts.total_energy_j);
    }
    println!("(d) image-generation energy per patch\n{}", td.render());

    let ratio_d =
        img_e.iter().cloned().fold(0.0, f64::max) / img_e.iter().cloned().fold(f64::MAX, f64::min);
    let summary = format!(
        "5b HF/SGLang ratio {ratio_b:.2}x (paper <=2.97x) | 5c conv spread {ratio_c:.2}x (paper <=3.35x) | 5d spread {ratio_d:.2}x"
    );
    println!("{summary}");
    persist("fig5_energy_comparison", &format!("{summary}\n"), Some(&csv));
    persist_json(
        "BENCH_fig5_energy_comparison",
        &Json::obj()
            .field("bench", "fig5_energy_comparison")
            .field("hf_sglang_ratio", ratio_b)
            .field("conv_spread", ratio_c)
            .field("unet_spread", ratio_d)
            .build(),
    );
    assert!(ratio_b > 1.3, "HF must be markedly less efficient than SGLang");
    assert!(ratio_c > 1.5, "conv energy spread must be large");
}
