//! Stream-scaling bench: (1) NVML readout cost vs trace length — the
//! incremental sampler cursor must scale near-linearly where the old
//! from-scratch re-simulation (retained as the `*_rescan` reference
//! path, selectable via `--rescan-only`) is quadratic; (2) the stream
//! auditor end-to-end on growing serving streams, with retained power
//! memory pinned at the ring capacity regardless of stream length.

use magneton::coordinator::fleet::StreamFleet;
use magneton::coordinator::SysRun;
use magneton::dispatch::Env;
use magneton::energy::sampler::NvmlSampler;
use magneton::energy::{DeviceSpec, PowerTrace};
use magneton::util::bench::{banner, persist, time_once};
use magneton::util::cli::Args;
use magneton::util::table::{fmt_joules, fmt_us, Table};
use magneton::util::Prng;
use magneton::workload::{serving_dispatcher, serving_stream_program, ServingStream};

/// A trace of `n` one-millisecond segments with varied power.
fn mk_trace(n: usize) -> PowerTrace {
    let mut tr = PowerTrace::new(90.0);
    for i in 0..n {
        tr.push(1000.0, 120.0 + (i % 97) as f64 * 4.0);
    }
    tr
}

fn main() {
    banner(
        "Stream scaling",
        "Incremental sampler cursor vs from-scratch rescan + bounded-memory stream audits",
    );
    let args = Args::from_env();
    let rescan_only = args.flag("rescan-only");

    // --- part 1: full-trace readout cost vs trace length -----------------
    // 1 kHz sampler over 1 ms segments: samples ≈ segments, so the
    // rescan path does Θ(n²) EMA steps where the cursor does Θ(n).
    let nvml = NvmlSampler { sample_hz: 1000.0, latency_us: 5_000.0, ema_alpha: 0.6 };
    let mut t = Table::new(vec!["segments", "old (rescan)", "new (cursor)", "speedup"]);
    let mut csv = String::from("segments,rescan_us,cursor_us\n");
    let sizes = [500usize, 1000, 2000, 4000];
    let mut cursor_us = Vec::new();
    let mut speedups = Vec::new();
    for &n in &sizes {
        let tr = mk_trace(n);
        let span = tr.duration_us();
        let mut old_best = f64::INFINITY;
        let mut new_best = f64::INFINITY;
        let mut e_old = 0.0;
        let mut e_new = 0.0;
        for _ in 0..3 {
            let (e, us) = time_once(|| nvml.energy_j_rescan(&tr, 0.0, span));
            e_old = e;
            old_best = old_best.min(us);
            if !rescan_only {
                let (e2, us2) = time_once(|| nvml.energy_j(&tr, 0.0, span));
                e_new = e2;
                new_best = new_best.min(us2);
            }
        }
        if !rescan_only {
            // the fix changed the complexity, not the answer
            assert_eq!(
                e_old.to_bits(),
                e_new.to_bits(),
                "cursor diverges from rescan at n={n}: {e_new} vs {e_old}"
            );
        }
        t.row(vec![
            n.to_string(),
            fmt_us(old_best),
            if rescan_only { "-".into() } else { fmt_us(new_best) },
            if rescan_only { "-".into() } else { format!("{:.0}x", old_best / new_best.max(1e-9)) },
        ]);
        let cursor_csv = if rescan_only { "NA".to_string() } else { format!("{new_best:.1}") };
        csv.push_str(&format!("{n},{old_best:.1},{cursor_csv}\n"));
        cursor_us.push(new_best);
        speedups.push(old_best / new_best.max(1e-9));
    }
    let part1 = t.render();
    println!("{part1}");

    if !rescan_only {
        // quadratic-vs-linear signature: the rescan/cursor gap must widen
        // as the trace grows
        assert!(
            speedups[sizes.len() - 1] > speedups[0],
            "speedup did not grow with trace length: {speedups:?}"
        );
        // near-linear cursor: 8x the segments must stay well under the
        // 64x a quadratic readout would cost (generous noise margin)
        assert!(
            cursor_us[sizes.len() - 1] < cursor_us[0].max(1.0) * 40.0,
            "cursor readout not near-linear: {cursor_us:?}"
        );
    }

    // --- part 2: stream audits with length-independent memory ------------
    let mut t2 = Table::new(vec![
        "stream ops", "wall", "wasted", "peak ring segs", "windows",
    ]);
    let mut csv2 = String::from("ops,wall_us,wasted_j,peak_ring\n");
    let ring_cap = 128;
    let mut peaks = Vec::new();
    for requests in [100usize, 200, 400] {
        let spec = ServingStream { requests, batch: 64, d_model: 128 };
        let mut fleet = StreamFleet::new(DeviceSpec::h200_sim());
        fleet.cfg.window_ops = 100;
        fleet.cfg.hop_ops = 100;
        fleet.cfg.ring_cap = ring_cap;
        let mut ra = Prng::new(7);
        let mut rb = Prng::new(7);
        fleet.add_pair(
            "serving",
            SysRun::new("a", serving_dispatcher(0.6), Env::new(), serving_stream_program(&mut ra, &spec)),
            SysRun::new("b", serving_dispatcher(1.0), Env::new(), serving_stream_program(&mut rb, &spec)),
        );
        let (report, wall_us) = time_once(|| fleet.run());
        let s = &report.entries[0].summary;
        assert!(s.aligned);
        assert!(s.wasted_j > 0.0, "0.6-efficiency stream must be flagged");
        assert!(
            s.peak_retained_segments <= ring_cap,
            "ring overflow: {} > {ring_cap}",
            s.peak_retained_segments
        );
        t2.row(vec![
            s.ops.to_string(),
            fmt_us(wall_us),
            fmt_joules(s.wasted_j),
            format!("{}/{}", s.peak_retained_segments, ring_cap),
            format!("{} ({} flagged)", s.windows, s.windows_flagged),
        ]);
        csv2.push_str(&format!(
            "{},{wall_us:.0},{},{}\n",
            s.ops, s.wasted_j, s.peak_retained_segments
        ));
        peaks.push(s.peak_retained_segments);
    }
    // memory is set by the ring, not the stream: peaks identical across
    // a 4x stream-length spread
    assert!(peaks.windows(2).all(|w| w[0] == w[1]), "peaks vary: {peaks:?}");
    let part2 = t2.render();
    println!("{part2}");

    persist("stream_scaling", &format!("{part1}\n{part2}"), Some(&format!("{csv}\n{csv2}")));
}
