//! Stream-scaling bench: (1) NVML readout cost vs trace length — the
//! incremental sampler cursor must scale near-linearly where the old
//! from-scratch re-simulation (retained as the `*_rescan` reference
//! path, selectable via `--rescan-only`) is quadratic; (2) the stream
//! auditor end-to-end on growing serving streams, with retained power
//! memory pinned at the ring capacity regardless of stream length.

use magneton::coordinator::fleet::StreamFleet;
use magneton::coordinator::SysRun;
use magneton::dispatch::Env;
use magneton::energy::sampler::NvmlSampler;
use magneton::energy::{DeviceSpec, PowerTrace};
use magneton::exec::Executor;
use magneton::stream::{StreamAuditor, StreamConfig};
use magneton::util::bench::{banner, persist, persist_json, time_once};
use magneton::util::cli::Args;
use magneton::util::json::Json;
use magneton::util::table::{fmt_joules, fmt_us, Table};
use magneton::util::Prng;
use magneton::workload::{serving_dispatcher, serving_stream_program, ServingStream};

/// A trace of `n` one-millisecond segments with varied power.
fn mk_trace(n: usize) -> PowerTrace {
    let mut tr = PowerTrace::new(90.0);
    for i in 0..n {
        tr.push(1000.0, 120.0 + (i % 97) as f64 * 4.0);
    }
    tr
}

fn main() {
    banner(
        "Stream scaling",
        "Incremental sampler cursor vs from-scratch rescan + bounded-memory stream audits",
    );
    let args = Args::from_env();
    let rescan_only = args.flag("rescan-only");

    // --- part 1: full-trace readout cost vs trace length -----------------
    // 1 kHz sampler over 1 ms segments: samples ≈ segments, so the
    // rescan path does Θ(n²) EMA steps where the cursor does Θ(n).
    let nvml = NvmlSampler { sample_hz: 1000.0, latency_us: 5_000.0, ema_alpha: 0.6 };
    let mut t = Table::new(vec!["segments", "old (rescan)", "new (cursor)", "speedup"]);
    let mut csv = String::from("segments,rescan_us,cursor_us\n");
    let sizes = [500usize, 1000, 2000, 4000];
    let mut cursor_us = Vec::new();
    let mut speedups = Vec::new();
    for &n in &sizes {
        let tr = mk_trace(n);
        let span = tr.duration_us();
        let mut old_best = f64::INFINITY;
        let mut new_best = f64::INFINITY;
        let mut e_old = 0.0;
        let mut e_new = 0.0;
        for _ in 0..3 {
            let (e, us) = time_once(|| nvml.energy_j_rescan(&tr, 0.0, span));
            e_old = e;
            old_best = old_best.min(us);
            if !rescan_only {
                let (e2, us2) = time_once(|| nvml.energy_j(&tr, 0.0, span));
                e_new = e2;
                new_best = new_best.min(us2);
            }
        }
        if !rescan_only {
            // the fix changed the complexity, not the answer
            assert_eq!(
                e_old.to_bits(),
                e_new.to_bits(),
                "cursor diverges from rescan at n={n}: {e_new} vs {e_old}"
            );
        }
        t.row(vec![
            n.to_string(),
            fmt_us(old_best),
            if rescan_only { "-".into() } else { fmt_us(new_best) },
            if rescan_only { "-".into() } else { format!("{:.0}x", old_best / new_best.max(1e-9)) },
        ]);
        let cursor_csv = if rescan_only { "NA".to_string() } else { format!("{new_best:.1}") };
        csv.push_str(&format!("{n},{old_best:.1},{cursor_csv}\n"));
        cursor_us.push(new_best);
        speedups.push(old_best / new_best.max(1e-9));
    }
    let part1 = t.render();
    println!("{part1}");

    if !rescan_only {
        // quadratic-vs-linear signature: the rescan/cursor gap must widen
        // as the trace grows
        assert!(
            speedups[sizes.len() - 1] > speedups[0],
            "speedup did not grow with trace length: {speedups:?}"
        );
        // near-linear cursor: 8x the segments must stay well under the
        // 64x a quadratic readout would cost (generous noise margin)
        assert!(
            cursor_us[sizes.len() - 1] < cursor_us[0].max(1.0) * 40.0,
            "cursor readout not near-linear: {cursor_us:?}"
        );
    }

    // --- part 2: stream audits with length-independent memory ------------
    let mut t2 = Table::new(vec![
        "stream ops", "wall", "wasted", "peak ring segs", "windows",
    ]);
    let mut csv2 = String::from("ops,wall_us,wasted_j,peak_ring\n");
    let ring_cap = 128;
    let mut peaks = Vec::new();
    for requests in [100usize, 200, 400] {
        let spec = ServingStream { requests, batch: 64, d_model: 128 };
        let mut fleet = StreamFleet::new(DeviceSpec::h200_sim());
        fleet.cfg.window_ops = 100;
        fleet.cfg.hop_ops = 100;
        fleet.cfg.ring_cap = ring_cap;
        let mut ra = Prng::new(7);
        let mut rb = Prng::new(7);
        fleet.add_pair(
            "serving",
            SysRun::new("a", serving_dispatcher(0.6), Env::new(), serving_stream_program(&mut ra, &spec)),
            SysRun::new("b", serving_dispatcher(1.0), Env::new(), serving_stream_program(&mut rb, &spec)),
        );
        let (report, wall_us) = time_once(|| fleet.run());
        let s = &report.entries[0].summary;
        assert!(s.aligned);
        assert!(s.wasted_j > 0.0, "0.6-efficiency stream must be flagged");
        assert!(
            s.peak_retained_segments <= ring_cap,
            "ring overflow: {} > {ring_cap}",
            s.peak_retained_segments
        );
        t2.row(vec![
            s.ops.to_string(),
            fmt_us(wall_us),
            fmt_joules(s.wasted_j),
            format!("{}/{}", s.peak_retained_segments, ring_cap),
            format!("{} ({} flagged)", s.windows, s.windows_flagged),
        ]);
        csv2.push_str(&format!(
            "{},{wall_us:.0},{},{}\n",
            s.ops, s.wasted_j, s.peak_retained_segments
        ));
        peaks.push(s.peak_retained_segments);
    }
    // memory is set by the ring, not the stream: peaks identical across
    // a 4x stream-length spread
    assert!(peaks.windows(2).all(|w| w[0] == w[1]), "peaks vary: {peaks:?}");
    let part2 = t2.render();
    println!("{part2}");

    // --- part 3: resynchronisation keeps a dropped kernel local ----------
    // One kernel skipped mid-stream on side A of an otherwise identical
    // pair. With resync, the damage is exactly one quarantined window no
    // matter how long the stream runs; with resync disabled (the
    // pre-fix behaviour) every window after the skip is poisoned.
    let mut t3 = Table::new(vec!["stream ops", "mode", "resyncs", "poisoned windows", "flagged", "wasted"]);
    let mut csv3 = String::from("ops,mode,resyncs,poisoned,flagged\n");
    let mut poisoned_by_mode: Vec<(usize, &str, usize)> = Vec::new();
    for requests in [100usize, 200] {
        let spec = ServingStream { requests, batch: 64, d_model: 128 };
        for (mode, lookahead) in [("resync", 64usize), ("no-resync", 0)] {
            let cfg = StreamConfig {
                window_ops: 50,
                hop_ops: 50,
                ring_cap: 128,
                resync_lookahead: lookahead,
                nvml: None,
                ..Default::default()
            };
            let dev = DeviceSpec::h200_sim();
            let mut rng_a = Prng::new(7);
            let mut rng_b = Prng::new(7);
            let prog_a = serving_stream_program(&mut rng_a, &spec);
            let prog_b = serving_stream_program(&mut rng_b, &spec);
            let exec_a = Executor::new(dev.clone(), serving_dispatcher(1.0), Env::new());
            let exec_b = Executor::new(dev.clone(), serving_dispatcher(1.0), Env::new());
            let mut sa = exec_a.stream(&prog_a);
            let mut sb = exec_b.stream(&prog_b);
            let mut aud = StreamAuditor::new(cfg, dev.idle_w);
            let skip_at = spec.kernel_ops() / 2;
            let mut i = 0usize;
            let mut poisoned = 0usize;
            loop {
                let mut na = sa.next();
                if i == skip_at {
                    na = sa.next(); // drop one side-A kernel on the floor
                }
                let nb = sb.next();
                if na.is_none() && nb.is_none() {
                    break;
                }
                if let Some((rec, seg)) = na {
                    aud.ingest_a(&rec, seg);
                }
                if let Some((rec, seg)) = nb {
                    aud.ingest_b(&rec, seg);
                }
                i += 1;
                for w in aud.take_emitted() {
                    if w.quarantined || !w.aligned {
                        poisoned += 1;
                    }
                }
            }
            let s = aud.finish();
            for w in aud.take_emitted() {
                if w.quarantined || !w.aligned {
                    poisoned += 1;
                }
            }
            if lookahead > 0 {
                assert_eq!(s.resyncs, 1, "exactly one re-anchor expected");
                assert_eq!(poisoned, 1, "resync must localise the skip to one window");
                assert_eq!(s.windows_flagged, 0, "no spurious findings after re-anchor");
                assert_eq!(s.wasted_j, 0.0);
            } else {
                assert!(s.wasted_j > 0.0, "shifted pairing must flag garbage waste");
            }
            t3.row(vec![
                s.ops.to_string(),
                mode.to_string(),
                s.resyncs.to_string(),
                poisoned.to_string(),
                s.windows_flagged.to_string(),
                fmt_joules(s.wasted_j),
            ]);
            csv3.push_str(&format!("{},{mode},{},{poisoned},{}\n", s.ops, s.resyncs, s.windows_flagged));
            poisoned_by_mode.push((requests, mode, poisoned));
        }
    }
    // locality signature: without resync the poisoned-window count grows
    // with stream length; with resync it is pinned at one
    let no_resync: Vec<usize> = poisoned_by_mode.iter().filter(|x| x.1 == "no-resync").map(|x| x.2).collect();
    assert!(no_resync[1] > no_resync[0], "no-resync poisoning did not grow: {no_resync:?}");
    let part3 = t3.render();
    println!("{part3}");

    persist(
        "stream_scaling",
        &format!("{part1}\n{part2}\n{part3}"),
        Some(&format!("{csv}\n{csv2}\n{csv3}")),
    );
    persist_json(
        "BENCH_stream_scaling",
        &Json::obj()
            .field("bench", "stream_scaling")
            .field("segments", sizes.iter().map(|&n| Json::Num(n as f64)).collect::<Vec<_>>())
            .field("cursor_us", cursor_us.iter().map(|&x| Json::Num(x)).collect::<Vec<_>>())
            .field("speedups", speedups.iter().map(|&x| Json::Num(x)).collect::<Vec<_>>())
            .field(
                "peak_ring_segments",
                peaks.iter().map(|&p| Json::Num(p as f64)).collect::<Vec<_>>(),
            )
            .field("rescan_only", rescan_only)
            .build(),
    );
}
