//! Integration tests for the static energy lint: manifest rediscovery
//! of known cases, bit-determinism across worker counts, and the
//! measure-after-fix loop confirming a static estimate with a measured
//! energy delta through the differential pipeline.

use magneton::analysis::{
    builtin_targets, check_manifest, lint_suite, parse_manifest, verify_finding, LintReport,
};
use magneton::energy::DeviceSpec;

fn suite(threads: usize) -> LintReport {
    lint_suite(&builtin_targets(7), &DeviceSpec::h200_sim(), threads)
}

/// The committed manifest must be fully rediscovered: every declared
/// (target, rule, label) triple appears among the static findings —
/// including the entries that re-find dynamic cases c2/c4/c5/c7/c9
/// without executing anything.
#[test]
fn manifest_findings_are_rediscovered() {
    let text = include_str!("lint_manifest.txt");
    let expected = parse_manifest(text).unwrap();
    assert!(expected.len() >= 6, "manifest lost entries");
    let report = suite(2);
    let unmet = check_manifest(&report, &expected);
    assert!(
        unmet.is_empty(),
        "expected findings missing: {:?}\nactual: {:?}",
        unmet,
        report
            .targets
            .iter()
            .flat_map(|t| t.findings.iter().map(move |f| (&t.name, f.rule, &f.label)))
            .collect::<Vec<_>>()
    );
}

/// Acceptance: the suite flags at least five distinct rule classes
/// across the built-in system programs.
#[test]
fn at_least_five_distinct_rule_classes_fire() {
    let report = suite(2);
    let mut rules: Vec<&str> =
        report.targets.iter().flat_map(|t| t.findings.iter().map(|f| f.rule)).collect();
    rules.sort_unstable();
    rules.dedup();
    assert!(rules.len() >= 5, "only {} rule classes fired: {rules:?}", rules.len());
}

/// Findings must be bit-identical across repeated runs and across
/// `util::pool` worker counts: same ordering, same node sets, same
/// `est_wasted_j` bit patterns.
#[test]
fn findings_are_bit_deterministic_across_worker_counts() {
    let runs: Vec<LintReport> = vec![suite(1), suite(1), suite(4), suite(8)];
    let fingerprint = |r: &LintReport| -> Vec<(String, &'static str, String, Vec<usize>, u64)> {
        r.targets
            .iter()
            .flat_map(|t| {
                t.findings.iter().map(move |f| {
                    (
                        t.name.clone(),
                        f.rule,
                        f.label.clone(),
                        f.nodes.clone(),
                        f.est_wasted_j.to_bits(),
                    )
                })
            })
            .collect()
    };
    let base = fingerprint(&runs[0]);
    assert!(!base.is_empty());
    for (i, r) in runs.iter().enumerate().skip(1) {
        assert_eq!(base, fingerprint(r), "run {i} diverged");
        assert_eq!(
            runs[0].total_est_wasted_j.to_bits(),
            r.total_est_wasted_j.to_bits(),
            "run {i} total diverged"
        );
    }
}

/// Acceptance: `--verify` on the c9 barrier — the measured energy delta
/// of applying the suggested rewrite has the same sign as the static
/// estimate, and the differential detector itself flags the pair.
#[test]
fn verify_confirms_c9_barrier_with_same_sign_delta() {
    let device = DeviceSpec::h200_sim();
    let targets = builtin_targets(7);
    let report = lint_suite(&targets, &device, 1);
    let idx = report.targets.iter().position(|t| t.name == "case-c9").unwrap();
    let finding = report.targets[idx]
        .findings
        .iter()
        .find(|f| f.rule == "redundant-sync")
        .expect("c9 barrier finding");
    let v = verify_finding(&targets[idx].run, finding, &device).unwrap();
    assert!(v.same_sign, "static {} vs measured {}", v.est_wasted_j, v.measured_delta_j);
    assert!(v.measured_delta_j > 0.0, "fix must save energy, got {}", v.measured_delta_j);
    assert!(
        v.energy_after_j < v.energy_before_j,
        "after {} !< before {}",
        v.energy_after_j,
        v.energy_before_j
    );
    // the barrier burns a fixed busy-wait; static and measured should
    // agree closely, not just in sign
    let rel = (v.measured_delta_j - v.est_wasted_j).abs() / v.est_wasted_j;
    assert!(rel < 0.2, "static {} vs measured {}", v.est_wasted_j, v.measured_delta_j);
}

/// The kv-cache staging copies of c2 are rediscovered statically and
/// their removal verifies with a positive measured delta too.
#[test]
fn verify_confirms_c2_redundant_copy() {
    let device = DeviceSpec::h200_sim();
    let targets = builtin_targets(7);
    let report = lint_suite(&targets, &device, 1);
    let idx = report.targets.iter().position(|t| t.name == "case-c2").unwrap();
    let copies: Vec<_> = report.targets[idx]
        .findings
        .iter()
        .filter(|f| f.rule == "redundant-copy")
        .collect();
    assert_eq!(copies.len(), 2, "both kv copies should be flagged");
    let v = verify_finding(&targets[idx].run, copies[0], &device).unwrap();
    assert!(v.same_sign && v.measured_delta_j > 0.0);
}
