//! Integration tests for the static energy lint: manifest rediscovery
//! of known cases, bit-determinism across worker counts, and the
//! measure-after-fix loop confirming a static estimate with a measured
//! energy delta through the differential pipeline.

use magneton::analysis::{
    builtin_targets, check_manifest, diff_suite, diff_targets, gate_manifest, interact_suite,
    interact_target, lint_suite, parse_manifest, verify_finding, InteractConfig, LintReport,
    StaticDiffConfig,
};
use magneton::energy::DeviceSpec;

fn suite(threads: usize) -> LintReport {
    lint_suite(&builtin_targets(7), &DeviceSpec::h200_sim(), threads)
}

/// The committed manifest must be fully rediscovered: every declared
/// (target, rule, label) triple appears among the static findings —
/// including the entries that re-find dynamic cases c2/c4/c5/c7/c8/c9
/// without executing anything, and the `diff~a~b` pseudo-target entries
/// the static differential audit produces under `lint --diff`.
#[test]
fn manifest_findings_are_rediscovered() {
    let text = include_str!("lint_manifest.txt");
    let expected = parse_manifest(text).unwrap();
    assert!(expected.len() >= 6, "manifest lost entries");
    assert!(
        expected.iter().any(|e| e.target.starts_with("diff~")),
        "manifest lost its static-diff entries"
    );
    assert!(
        expected.iter().any(|e| e.target.starts_with("interact~")),
        "manifest lost its interaction entries"
    );
    let mut report = suite(2);
    // the CLI's --diff mode: every same-family pair diff joins the
    // report as a `diff~a~b` pseudo-target
    let cfg = StaticDiffConfig::default();
    for d in diff_suite(&builtin_targets(7), &DeviceSpec::h200_sim(), 2, &cfg) {
        assert!(d.error.is_none(), "{} vs {}: {:?}", d.target_a, d.target_b, d.error);
        report.targets.push(d.to_target_report(&cfg));
    }
    // the CLI's --interact mode: joint-search diagnoses join as
    // `interact~<target>` pseudo-targets
    let icfg = InteractConfig::default();
    for ir in interact_suite(&builtin_targets(7), &DeviceSpec::h200_sim(), 2, &icfg) {
        assert!(ir.error.is_none(), "{}: {:?}", ir.target, ir.error);
        report.targets.push(ir.to_target_report());
    }
    let unmet = check_manifest(&report, &expected);
    assert!(
        unmet.is_empty(),
        "expected findings missing: {:?}\nactual: {:?}",
        unmet,
        report
            .targets
            .iter()
            .flat_map(|t| t.findings.iter().map(move |f| (&t.name, f.rule, &f.label)))
            .collect::<Vec<_>>()
    );
}

/// Acceptance: the suite flags at least five distinct rule classes
/// across the built-in system programs.
#[test]
fn at_least_five_distinct_rule_classes_fire() {
    let report = suite(2);
    let mut rules: Vec<&str> =
        report.targets.iter().flat_map(|t| t.findings.iter().map(|f| f.rule)).collect();
    rules.sort_unstable();
    rules.dedup();
    assert!(rules.len() >= 5, "only {} rule classes fired: {rules:?}", rules.len());
}

/// Findings must be bit-identical across repeated runs and across
/// `util::pool` worker counts: same ordering, same node sets, same
/// `est_wasted_j` bit patterns.
#[test]
fn findings_are_bit_deterministic_across_worker_counts() {
    let runs: Vec<LintReport> = vec![suite(1), suite(1), suite(4), suite(8)];
    let fingerprint = |r: &LintReport| -> Vec<(String, &'static str, String, Vec<usize>, u64)> {
        r.targets
            .iter()
            .flat_map(|t| {
                t.findings.iter().map(move |f| {
                    (
                        t.name.clone(),
                        f.rule,
                        f.label.clone(),
                        f.nodes.clone(),
                        f.est_wasted_j.to_bits(),
                    )
                })
            })
            .collect()
    };
    let base = fingerprint(&runs[0]);
    assert!(!base.is_empty());
    for (i, r) in runs.iter().enumerate().skip(1) {
        assert_eq!(base, fingerprint(r), "run {i} diverged");
        assert_eq!(
            runs[0].total_est_wasted_j.to_bits(),
            r.total_est_wasted_j.to_bits(),
            "run {i} total diverged"
        );
    }
}

/// Acceptance: `--verify` on the c9 barrier — the measured energy delta
/// of applying the suggested rewrite has the same sign as the static
/// estimate, and the differential detector itself flags the pair.
#[test]
fn verify_confirms_c9_barrier_with_same_sign_delta() {
    let device = DeviceSpec::h200_sim();
    let targets = builtin_targets(7);
    let report = lint_suite(&targets, &device, 1);
    let idx = report.targets.iter().position(|t| t.name == "case-c9").unwrap();
    let finding = report.targets[idx]
        .findings
        .iter()
        .find(|f| f.rule == "redundant-sync")
        .expect("c9 barrier finding");
    let v = verify_finding(&targets[idx].run, finding, &device).unwrap();
    assert!(v.same_sign, "static {} vs measured {}", v.est_wasted_j, v.measured_delta_j);
    assert!(v.measured_delta_j > 0.0, "fix must save energy, got {}", v.measured_delta_j);
    assert!(
        v.energy_after_j < v.energy_before_j,
        "after {} !< before {}",
        v.energy_after_j,
        v.energy_before_j
    );
    // the barrier burns a fixed busy-wait; static and measured should
    // agree closely, not just in sign
    let rel = (v.measured_delta_j - v.est_wasted_j).abs() / v.est_wasted_j;
    assert!(rel < 0.2, "static {} vs measured {}", v.est_wasted_j, v.measured_delta_j);
}

/// The kv-cache staging copies of c2 are rediscovered statically and
/// their removal verifies with a positive measured delta too.
#[test]
fn verify_confirms_c2_redundant_copy() {
    let device = DeviceSpec::h200_sim();
    let targets = builtin_targets(7);
    let report = lint_suite(&targets, &device, 1);
    let idx = report.targets.iter().position(|t| t.name == "case-c2").unwrap();
    let copies: Vec<_> = report.targets[idx]
        .findings
        .iter()
        .filter(|f| f.rule == "redundant-copy")
        .collect();
    assert_eq!(copies.len(), 2, "both kv copies should be flagged");
    let v = verify_finding(&targets[idx].run, copies[0], &device).unwrap();
    assert!(v.same_sign && v.measured_delta_j > 0.0);
}

/// The static differential audit must also be bit-identical across
/// worker counts: same pair order, same matched regions, same delta bit
/// patterns, same unmatched attribution.
#[test]
fn static_diff_is_bit_deterministic_across_worker_counts() {
    let device = DeviceSpec::h200_sim();
    let targets = builtin_targets(7);
    let cfg = StaticDiffConfig::default();
    type Fp = Vec<(String, String, Vec<(usize, usize, u64)>, usize, usize)>;
    let fp = |threads: usize| -> Fp {
        diff_suite(&targets, &device, threads, &cfg)
            .iter()
            .map(|d| {
                (
                    d.target_a.clone(),
                    d.target_b.clone(),
                    d.regions
                        .iter()
                        .map(|r| (r.node_a, r.node_b, r.delta_j.to_bits()))
                        .collect(),
                    d.unmatched_a.len(),
                    d.unmatched_b.len(),
                )
            })
            .collect()
    };
    let base = fp(1);
    assert!(!base.is_empty(), "no same-family pairs to diff");
    for threads in [2, 4, 8] {
        assert_eq!(base, fp(threads), "{threads} workers diverged");
    }
}

/// Symbolic dispatch enumeration is deterministic and covers both sides
/// of the tf32 branch — the substrate of the `dtype-downcast` rule.
#[test]
fn dispatch_enumeration_is_deterministic_and_total() {
    let fp = || -> Vec<(Vec<(String, String)>, usize, String)> {
        magneton::systems::torch_matmul_routine()
            .enumerate_outcomes()
            .into_iter()
            .map(|o| (o.assignment.into_iter().collect(), o.choice_idx, o.choice.kernel))
            .collect()
    };
    let base = fp();
    assert!(base.len() >= 2, "expected both branch assignments: {base:?}");
    assert_eq!(base, fp());
    let kernels: Vec<&str> = base.iter().map(|(_, _, k)| k.as_str()).collect();
    assert!(kernels.iter().any(|k| k.contains("tf32")), "{kernels:?}");
    assert!(kernels.iter().any(|k| !k.contains("tf32")), "{kernels:?}");
}

/// Negative control: diffing a target against itself matches every
/// billed region at the hash tier with a bitwise-zero delta and yields
/// no findings.
#[test]
fn identical_targets_produce_an_empty_static_diff() {
    let device = DeviceSpec::h200_sim();
    let cfg = StaticDiffConfig::default();
    let targets = builtin_targets(7);
    let sd = targets.iter().find(|t| t.name == "mini-stable-diffusion").unwrap();
    let rep = diff_targets(sd, sd, &device, &cfg).unwrap();
    assert!(!rep.regions.is_empty());
    assert!(rep.unmatched_a.is_empty() && rep.unmatched_b.is_empty());
    assert!(rep.regions.iter().all(|r| r.delta_j == 0.0), "self-diff must be flat");
    assert_eq!(rep.total_a_j.to_bits(), rep.total_b_j.to_bits());
    let findings = rep.findings(&cfg);
    assert!(findings.is_empty(), "self-diff produced findings: {findings:?}");
}

/// The c8 known case is rediscovered fully statically: the symbolic
/// dispatch pass names the responsible config flag and its cheaper
/// assignment, and `--verify` confirms the SetAttr rewrite with a
/// positive measured delta.
#[test]
fn verify_confirms_c8_dtype_downcast_names_the_flag() {
    let device = DeviceSpec::h200_sim();
    let targets = builtin_targets(7);
    let report = lint_suite(&targets, &device, 1);
    let idx = report.targets.iter().position(|t| t.name == "case-c8").unwrap();
    let f = report.targets[idx]
        .findings
        .iter()
        .find(|f| f.rule == "dtype-downcast")
        .expect("c8 dtype-downcast finding");
    assert!(
        f.suggestion.contains("torch.backends.cuda.matmul.allow_tf32"),
        "must name the responsible flag: {}",
        f.suggestion
    );
    assert!(
        f.suggestion.contains("allow_tf32=true"),
        "must name the cheaper assignment: {}",
        f.suggestion
    );
    assert!(!f.steps.is_empty(), "dtype-downcast must carry SetAttr rewrites");
    let v = verify_finding(&targets[idx].run, f, &device).unwrap();
    assert!(v.same_sign, "static {} vs measured {}", v.est_wasted_j, v.measured_delta_j);
    assert!(v.measured_delta_j > 0.0, "fix must save energy, got {}", v.measured_delta_j);
}

/// The joint interaction search must be bit-identical across worker
/// counts: same diagnoses, same flag sets, same saving bit patterns,
/// same search-effort counters.
#[test]
fn interaction_search_is_bit_deterministic_across_worker_counts() {
    let device = DeviceSpec::h200_sim();
    let targets = builtin_targets(7);
    let cfg = InteractConfig::default();
    let fp = |threads: usize| -> Vec<String> {
        interact_suite(&targets, &device, threads, &cfg)
            .iter()
            .map(|r| {
                let ds: Vec<String> = r
                    .diagnoses
                    .iter()
                    .map(|d| {
                        format!(
                            "{:?}@{:?}={:016x}:{}",
                            d.assignment,
                            d.nodes,
                            d.joint_saved_j.to_bits(),
                            d.label
                        )
                    })
                    .collect();
                format!(
                    "{} v{} p{} e{} x{} {ds:?}",
                    r.target,
                    r.stats.visited,
                    r.stats.pruned,
                    r.stats.evaluated,
                    r.stats.exhaustive
                )
            })
            .collect()
    };
    let base = fp(1);
    assert!(
        base.iter().any(|s| s.contains("allow_tf32")),
        "no interaction diagnoses on any builtin target: {base:?}"
    );
    for threads in [2, 4, 8] {
        assert_eq!(base, fp(threads), "{threads} workers diverged");
    }
}

/// Totality: every joint outcome the symbolic enumeration produces maps
/// to a concrete dispatch path — `launch_for` under the outcome's env
/// agrees with the enumerated choice index, and together the outcomes
/// cover the routine's whole kernel-choice table.
#[test]
fn every_joint_outcome_maps_to_a_concrete_dispatch_path() {
    use magneton::dispatch::Env;
    let r = magneton::systems::imagegen::joint_matmul_routine();
    let outcomes = r.enumerate_outcomes();
    // 2 flags x {unset, tested literal} = 4 joint outcomes, one per path
    assert_eq!(outcomes.len(), 4, "{outcomes:?}");
    let mut hit = vec![false; r.choices.len()];
    for o in &outcomes {
        let mut env = Env::new();
        for (k, v) in &o.assignment {
            env.set(k, v);
        }
        let idx = r.launch_for(&env);
        assert_eq!(idx, o.choice_idx, "assignment {:?}", o.assignment);
        assert!(idx < r.choices.len(), "outcome escaped the choice table");
        hit[idx] = true;
    }
    assert!(hit.iter().all(|&h| h), "some kernel choice is unreachable: {hit:?}");
}

/// Property: joint search dominates single-flag enumeration. On every
/// builtin target the per-node joint optimum saves at least as much as
/// all `dtype-downcast` findings combined — the joint space contains
/// every single flip under the same energy+time gate — and on the
/// engineered joint target it saves strictly more.
#[test]
fn joint_search_savings_dominate_single_flag_findings_on_every_target() {
    use magneton::analysis::interact::search_node;
    use magneton::analysis::LintContext;
    let device = DeviceSpec::h200_sim();
    let targets = builtin_targets(7);
    let report = lint_suite(&targets, &device, 2);
    let cfg = InteractConfig::default();
    let mut joint_beat_single_somewhere = false;
    for t in &targets {
        let cx = LintContext::new(&t.run.prog, &t.run.dispatcher, &t.run.env, &device).unwrap();
        let joint_total: f64 = cx
            .graph
            .nodes
            .iter()
            .filter_map(|n| search_node(&cx, n.id, &cfg))
            .filter_map(|s| s.hit.map(|h| h.saved_j))
            .sum();
        let single_total: f64 = report
            .targets
            .iter()
            .find(|r| r.name == t.name)
            .unwrap()
            .findings
            .iter()
            .filter(|f| f.rule == "dtype-downcast")
            .map(|f| f.est_wasted_j)
            .sum();
        assert!(
            joint_total >= single_total - 1e-12,
            "{}: joint {joint_total} < single {single_total}",
            t.name
        );
        if joint_total > single_total + 1e-12 {
            joint_beat_single_somewhere = true;
        }
    }
    assert!(joint_beat_single_somewhere, "joint search never beat single-flag enumeration");
}

/// Acceptance: on `case-c8-joint` the search reports a 1-minimal flag
/// set of two flags whose joint saving no single-flag flip can reach —
/// tf32 alone blows the time budget, the layout flag alone costs energy
/// — and the joint SetAttr rewrite sign-confirms under the measured
/// A/B.
#[test]
fn verify_confirms_joint_c8_interaction_end_to_end() {
    let device = DeviceSpec::h200_sim();
    let targets = builtin_targets(7);
    let report = lint_suite(&targets, &device, 1);
    let plain = report.targets.iter().find(|r| r.name == "case-c8-joint").unwrap();
    assert!(
        plain.findings.iter().all(|f| f.rule != "dtype-downcast"),
        "single-flag enumeration must not reach the joint saving: {:?}",
        plain.findings
    );
    let t = targets.iter().find(|t| t.name == "case-c8-joint").unwrap();
    let ir = interact_target(t, &device, &InteractConfig::default()).unwrap();
    assert_eq!(ir.diagnoses.len(), 1, "{:?}", ir.diagnoses);
    let d = &ir.diagnoses[0];
    assert_eq!(d.assignment.len(), 2, "1-minimal set must keep both flags: {:?}", d.assignment);
    assert!(d.assignment.iter().any(|(k, _)| k == "allow_tf32"), "{:?}", d.assignment);
    assert!(d.assignment.iter().any(|(k, _)| k == "channels_last"), "{:?}", d.assignment);
    assert!(d.label.contains("resnet.conv1"), "biggest saver should lead: {}", d.label);
    assert!(d.joint_saved_j > 0.0);
    let best_single = plain
        .findings
        .iter()
        .filter(|f| f.rule == "dtype-downcast")
        .map(|f| f.est_wasted_j)
        .fold(0.0f64, f64::max);
    assert!(
        d.joint_saved_j > best_single,
        "joint {} must strictly beat best single-flag finding {best_single}",
        d.joint_saved_j
    );
    // the marginal breakdown explains *why* the set is minimal
    let tf = d.marginals.iter().find(|m| m.flag == "allow_tf32").unwrap();
    assert!(!tf.time_ok, "tf32 alone must blow the time budget");
    let cl = d.marginals.iter().find(|m| m.flag == "channels_last").unwrap();
    assert!(cl.time_ok && cl.saved_j < 0.0, "layout alone must cost energy: {}", cl.saved_j);
    // end to end: the finding's joint SetAttr steps A/B-measure with the
    // same sign as the static estimate
    let f = ir.findings().into_iter().find(|f| !f.steps.is_empty()).unwrap();
    assert_eq!(f.rule, "interaction");
    let v = verify_finding(&t.run, &f, &device).unwrap();
    assert!(v.same_sign, "static {} vs measured {}", v.est_wasted_j, v.measured_delta_j);
    assert!(v.measured_delta_j > 0.0, "joint flip must save energy, got {}", v.measured_delta_j);
    assert!(v.energy_after_j < v.energy_before_j);
}

/// Regression (manifest partitioning): tagged pseudo-target entries are
/// gated strictly per enabled layer — `interact~` entries used to slip
/// through the old `diff~`-only filter and fail plain-run gating.
#[test]
fn manifest_gating_partitions_tagged_pseudo_targets() {
    let text = "case-c2 redundant-copy kv_k_copy\n\
                diff~a~b static-diff conv\n\
                interact~case-c8-joint interaction resnet.conv1\n";
    let all = parse_manifest(text).unwrap();
    assert_eq!(all.len(), 3);
    let plain = gate_manifest(all.clone(), &[("diff~", false), ("interact~", false)]);
    assert_eq!(plain.len(), 1, "{plain:?}");
    assert_eq!(plain[0].target, "case-c2");
    let diff_only = gate_manifest(all.clone(), &[("diff~", true), ("interact~", false)]);
    assert_eq!(diff_only.len(), 2, "{diff_only:?}");
    assert!(diff_only.iter().all(|e| !e.target.starts_with("interact~")));
    let both = gate_manifest(all, &[("diff~", true), ("interact~", true)]);
    assert_eq!(both.len(), 3);
    // the committed manifest, gated for a plain run, must pass against a
    // plain report — interact~/diff~ entries must not leak into it
    let committed = parse_manifest(include_str!("lint_manifest.txt")).unwrap();
    let gated = gate_manifest(committed, &[("diff~", false), ("interact~", false)]);
    assert!(gated.iter().all(|e| !e.target.contains('~')), "{gated:?}");
    let unmet = check_manifest(&suite(2), &gated);
    assert!(unmet.is_empty(), "plain-gated manifest unmet: {unmet:?}");
}

/// `lint --json` output round-trips through the telemetry JSON parser
/// with lossless floats — every energy figure comes back bit-identical
/// — and carries the interaction diagnoses alongside the findings.
#[test]
fn lint_json_report_round_trips_losslessly() {
    use magneton::report::lint_report_json;
    use magneton::telemetry::json::Json;
    let device = DeviceSpec::h200_sim();
    let targets = builtin_targets(7);
    let mut rep = lint_suite(&targets, &device, 2);
    for ir in interact_suite(&targets, &device, 2, &InteractConfig::default()) {
        rep.targets.push(ir.to_target_report());
    }
    rep.total_findings = rep.targets.iter().map(|t| t.findings.len()).sum();
    rep.total_est_wasted_j =
        rep.targets.iter().flat_map(|t| &t.findings).map(|f| f.est_wasted_j).sum();
    let text = lint_report_json(&rep).render();
    let back = Json::parse(&text).unwrap();
    let tjs = back.get("targets").unwrap().as_arr().unwrap();
    assert_eq!(tjs.len(), rep.targets.len());
    for (t, tj) in rep.targets.iter().zip(tjs) {
        assert_eq!(tj.get("name").unwrap().as_str(), Some(t.name.as_str()));
        assert_eq!(
            tj.get("static_j").unwrap().as_f64().unwrap().to_bits(),
            t.static_j.to_bits(),
            "{}: static_j drifted through JSON",
            t.name
        );
        let fjs = tj.get("findings").unwrap().as_arr().unwrap();
        assert_eq!(fjs.len(), t.findings.len());
        for (f, fj) in t.findings.iter().zip(fjs) {
            let est = fj.get("est_wasted_j").unwrap().as_f64().unwrap();
            assert_eq!(est.to_bits(), f.est_wasted_j.to_bits(), "{}", f.label);
        }
        let ijs = tj.get("interactions").unwrap().as_arr().unwrap();
        assert_eq!(ijs.len(), t.interactions.len());
        for (d, dj) in t.interactions.iter().zip(ijs) {
            let j = dj.get("joint_saved_j").unwrap().as_f64().unwrap();
            assert_eq!(j.to_bits(), d.joint_saved_j.to_bits(), "{}", d.label);
        }
    }
    let total = back.get("total_est_wasted_j").unwrap().as_f64().unwrap();
    assert_eq!(total.to_bits(), rep.total_est_wasted_j.to_bits());
    // the interact pseudo-target made it through with its flag set
    assert!(text.contains("interact~case-c8-joint"), "json missing interact pseudo-target");
    assert!(text.contains("allow_tf32"), "json missing the joint flag set");
}

/// The fixture's duplicated branch carries a full mechanical rewrite
/// (bypass + exclusive-cone removal) that sign-confirms under the
/// measured A/B.
#[test]
fn verify_confirms_lint_fixture_cse_bypass() {
    let device = DeviceSpec::h200_sim();
    let targets = builtin_targets(7);
    let report = lint_suite(&targets, &device, 1);
    let idx = report.targets.iter().position(|t| t.name == "lint-fixture").unwrap();
    let f = report.targets[idx]
        .findings
        .iter()
        .filter(|f| f.rule == "cse-duplicate")
        .max_by(|a, b| a.est_wasted_j.total_cmp(&b.est_wasted_j))
        .expect("cse-duplicate finding");
    let v = verify_finding(&targets[idx].run, f, &device).unwrap();
    assert!(v.same_sign, "static {} vs measured {}", v.est_wasted_j, v.measured_delta_j);
    assert!(v.measured_delta_j > 0.0, "bypass must save energy, got {}", v.measured_delta_j);
    assert!(v.energy_after_j < v.energy_before_j);
}
