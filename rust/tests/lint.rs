//! Integration tests for the static energy lint: manifest rediscovery
//! of known cases, bit-determinism across worker counts, and the
//! measure-after-fix loop confirming a static estimate with a measured
//! energy delta through the differential pipeline.

use magneton::analysis::{
    builtin_targets, check_manifest, diff_suite, diff_targets, lint_suite, parse_manifest,
    verify_finding, LintReport, StaticDiffConfig,
};
use magneton::energy::DeviceSpec;

fn suite(threads: usize) -> LintReport {
    lint_suite(&builtin_targets(7), &DeviceSpec::h200_sim(), threads)
}

/// The committed manifest must be fully rediscovered: every declared
/// (target, rule, label) triple appears among the static findings —
/// including the entries that re-find dynamic cases c2/c4/c5/c7/c8/c9
/// without executing anything, and the `diff~a~b` pseudo-target entries
/// the static differential audit produces under `lint --diff`.
#[test]
fn manifest_findings_are_rediscovered() {
    let text = include_str!("lint_manifest.txt");
    let expected = parse_manifest(text).unwrap();
    assert!(expected.len() >= 6, "manifest lost entries");
    assert!(
        expected.iter().any(|e| e.target.starts_with("diff~")),
        "manifest lost its static-diff entries"
    );
    let mut report = suite(2);
    // the CLI's --diff mode: every same-family pair diff joins the
    // report as a `diff~a~b` pseudo-target
    let cfg = StaticDiffConfig::default();
    for d in diff_suite(&builtin_targets(7), &DeviceSpec::h200_sim(), 2, &cfg) {
        assert!(d.error.is_none(), "{} vs {}: {:?}", d.target_a, d.target_b, d.error);
        report.targets.push(d.to_target_report(&cfg));
    }
    let unmet = check_manifest(&report, &expected);
    assert!(
        unmet.is_empty(),
        "expected findings missing: {:?}\nactual: {:?}",
        unmet,
        report
            .targets
            .iter()
            .flat_map(|t| t.findings.iter().map(move |f| (&t.name, f.rule, &f.label)))
            .collect::<Vec<_>>()
    );
}

/// Acceptance: the suite flags at least five distinct rule classes
/// across the built-in system programs.
#[test]
fn at_least_five_distinct_rule_classes_fire() {
    let report = suite(2);
    let mut rules: Vec<&str> =
        report.targets.iter().flat_map(|t| t.findings.iter().map(|f| f.rule)).collect();
    rules.sort_unstable();
    rules.dedup();
    assert!(rules.len() >= 5, "only {} rule classes fired: {rules:?}", rules.len());
}

/// Findings must be bit-identical across repeated runs and across
/// `util::pool` worker counts: same ordering, same node sets, same
/// `est_wasted_j` bit patterns.
#[test]
fn findings_are_bit_deterministic_across_worker_counts() {
    let runs: Vec<LintReport> = vec![suite(1), suite(1), suite(4), suite(8)];
    let fingerprint = |r: &LintReport| -> Vec<(String, &'static str, String, Vec<usize>, u64)> {
        r.targets
            .iter()
            .flat_map(|t| {
                t.findings.iter().map(move |f| {
                    (
                        t.name.clone(),
                        f.rule,
                        f.label.clone(),
                        f.nodes.clone(),
                        f.est_wasted_j.to_bits(),
                    )
                })
            })
            .collect()
    };
    let base = fingerprint(&runs[0]);
    assert!(!base.is_empty());
    for (i, r) in runs.iter().enumerate().skip(1) {
        assert_eq!(base, fingerprint(r), "run {i} diverged");
        assert_eq!(
            runs[0].total_est_wasted_j.to_bits(),
            r.total_est_wasted_j.to_bits(),
            "run {i} total diverged"
        );
    }
}

/// Acceptance: `--verify` on the c9 barrier — the measured energy delta
/// of applying the suggested rewrite has the same sign as the static
/// estimate, and the differential detector itself flags the pair.
#[test]
fn verify_confirms_c9_barrier_with_same_sign_delta() {
    let device = DeviceSpec::h200_sim();
    let targets = builtin_targets(7);
    let report = lint_suite(&targets, &device, 1);
    let idx = report.targets.iter().position(|t| t.name == "case-c9").unwrap();
    let finding = report.targets[idx]
        .findings
        .iter()
        .find(|f| f.rule == "redundant-sync")
        .expect("c9 barrier finding");
    let v = verify_finding(&targets[idx].run, finding, &device).unwrap();
    assert!(v.same_sign, "static {} vs measured {}", v.est_wasted_j, v.measured_delta_j);
    assert!(v.measured_delta_j > 0.0, "fix must save energy, got {}", v.measured_delta_j);
    assert!(
        v.energy_after_j < v.energy_before_j,
        "after {} !< before {}",
        v.energy_after_j,
        v.energy_before_j
    );
    // the barrier burns a fixed busy-wait; static and measured should
    // agree closely, not just in sign
    let rel = (v.measured_delta_j - v.est_wasted_j).abs() / v.est_wasted_j;
    assert!(rel < 0.2, "static {} vs measured {}", v.est_wasted_j, v.measured_delta_j);
}

/// The kv-cache staging copies of c2 are rediscovered statically and
/// their removal verifies with a positive measured delta too.
#[test]
fn verify_confirms_c2_redundant_copy() {
    let device = DeviceSpec::h200_sim();
    let targets = builtin_targets(7);
    let report = lint_suite(&targets, &device, 1);
    let idx = report.targets.iter().position(|t| t.name == "case-c2").unwrap();
    let copies: Vec<_> = report.targets[idx]
        .findings
        .iter()
        .filter(|f| f.rule == "redundant-copy")
        .collect();
    assert_eq!(copies.len(), 2, "both kv copies should be flagged");
    let v = verify_finding(&targets[idx].run, copies[0], &device).unwrap();
    assert!(v.same_sign && v.measured_delta_j > 0.0);
}

/// The static differential audit must also be bit-identical across
/// worker counts: same pair order, same matched regions, same delta bit
/// patterns, same unmatched attribution.
#[test]
fn static_diff_is_bit_deterministic_across_worker_counts() {
    let device = DeviceSpec::h200_sim();
    let targets = builtin_targets(7);
    let cfg = StaticDiffConfig::default();
    type Fp = Vec<(String, String, Vec<(usize, usize, u64)>, usize, usize)>;
    let fp = |threads: usize| -> Fp {
        diff_suite(&targets, &device, threads, &cfg)
            .iter()
            .map(|d| {
                (
                    d.target_a.clone(),
                    d.target_b.clone(),
                    d.regions
                        .iter()
                        .map(|r| (r.node_a, r.node_b, r.delta_j.to_bits()))
                        .collect(),
                    d.unmatched_a.len(),
                    d.unmatched_b.len(),
                )
            })
            .collect()
    };
    let base = fp(1);
    assert!(!base.is_empty(), "no same-family pairs to diff");
    for threads in [2, 4, 8] {
        assert_eq!(base, fp(threads), "{threads} workers diverged");
    }
}

/// Symbolic dispatch enumeration is deterministic and covers both sides
/// of the tf32 branch — the substrate of the `dtype-downcast` rule.
#[test]
fn dispatch_enumeration_is_deterministic_and_total() {
    let fp = || -> Vec<(Vec<(String, String)>, usize, String)> {
        magneton::systems::torch_matmul_routine()
            .enumerate_outcomes()
            .into_iter()
            .map(|o| (o.assignment.into_iter().collect(), o.choice_idx, o.choice.kernel))
            .collect()
    };
    let base = fp();
    assert!(base.len() >= 2, "expected both branch assignments: {base:?}");
    assert_eq!(base, fp());
    let kernels: Vec<&str> = base.iter().map(|(_, _, k)| k.as_str()).collect();
    assert!(kernels.iter().any(|k| k.contains("tf32")), "{kernels:?}");
    assert!(kernels.iter().any(|k| !k.contains("tf32")), "{kernels:?}");
}

/// Negative control: diffing a target against itself matches every
/// billed region at the hash tier with a bitwise-zero delta and yields
/// no findings.
#[test]
fn identical_targets_produce_an_empty_static_diff() {
    let device = DeviceSpec::h200_sim();
    let cfg = StaticDiffConfig::default();
    let targets = builtin_targets(7);
    let sd = targets.iter().find(|t| t.name == "mini-stable-diffusion").unwrap();
    let rep = diff_targets(sd, sd, &device, &cfg).unwrap();
    assert!(!rep.regions.is_empty());
    assert!(rep.unmatched_a.is_empty() && rep.unmatched_b.is_empty());
    assert!(rep.regions.iter().all(|r| r.delta_j == 0.0), "self-diff must be flat");
    assert_eq!(rep.total_a_j.to_bits(), rep.total_b_j.to_bits());
    let findings = rep.findings(&cfg);
    assert!(findings.is_empty(), "self-diff produced findings: {findings:?}");
}

/// The c8 known case is rediscovered fully statically: the symbolic
/// dispatch pass names the responsible config flag and its cheaper
/// assignment, and `--verify` confirms the SetAttr rewrite with a
/// positive measured delta.
#[test]
fn verify_confirms_c8_dtype_downcast_names_the_flag() {
    let device = DeviceSpec::h200_sim();
    let targets = builtin_targets(7);
    let report = lint_suite(&targets, &device, 1);
    let idx = report.targets.iter().position(|t| t.name == "case-c8").unwrap();
    let f = report.targets[idx]
        .findings
        .iter()
        .find(|f| f.rule == "dtype-downcast")
        .expect("c8 dtype-downcast finding");
    assert!(
        f.suggestion.contains("torch.backends.cuda.matmul.allow_tf32"),
        "must name the responsible flag: {}",
        f.suggestion
    );
    assert!(
        f.suggestion.contains("allow_tf32=true"),
        "must name the cheaper assignment: {}",
        f.suggestion
    );
    assert!(!f.steps.is_empty(), "dtype-downcast must carry SetAttr rewrites");
    let v = verify_finding(&targets[idx].run, f, &device).unwrap();
    assert!(v.same_sign, "static {} vs measured {}", v.est_wasted_j, v.measured_delta_j);
    assert!(v.measured_delta_j > 0.0, "fix must save energy, got {}", v.measured_delta_j);
}

/// The fixture's duplicated branch carries a full mechanical rewrite
/// (bypass + exclusive-cone removal) that sign-confirms under the
/// measured A/B.
#[test]
fn verify_confirms_lint_fixture_cse_bypass() {
    let device = DeviceSpec::h200_sim();
    let targets = builtin_targets(7);
    let report = lint_suite(&targets, &device, 1);
    let idx = report.targets.iter().position(|t| t.name == "lint-fixture").unwrap();
    let f = report.targets[idx]
        .findings
        .iter()
        .filter(|f| f.rule == "cse-duplicate")
        .max_by(|a, b| a.est_wasted_j.total_cmp(&b.est_wasted_j))
        .expect("cse-duplicate finding");
    let v = verify_finding(&targets[idx].run, f, &device).unwrap();
    assert!(v.same_sign, "static {} vs measured {}", v.est_wasted_j, v.measured_delta_j);
    assert!(v.measured_delta_j > 0.0, "bypass must save energy, got {}", v.measured_delta_j);
    assert!(v.energy_after_j < v.energy_before_j);
}
