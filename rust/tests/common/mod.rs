//! Shared test-support harness for the integration suites
//! (`integration.rs`, `properties.rs`, `telemetry.rs`, `session.rs`,
//! `golden.rs`): seeded program/stream builders, snapshot-dir fixtures,
//! and the cycle-op auditor harness, so each suite composes scenarios
//! instead of re-declaring builders.
#![allow(dead_code)] // each test binary compiles its own copy and uses a subset

use std::path::PathBuf;

use magneton::coordinator::fleet::StreamFleetEntry;
use magneton::coordinator::{Magneton, SysRun};
use magneton::dispatch::Env;
use magneton::energy::{DeviceSpec, Segment};
use magneton::exec::KernelRecord;
use magneton::graph::OpKind;
use magneton::stream::{StreamAuditor, StreamConfig, WindowReport};
use magneton::trace::Frame;
use magneton::util::Prng;
use magneton::workload::{serving_dispatcher, serving_stream_program, ServingStream};

/// Fresh per-test temp directory (removed first if a previous run left
/// it behind). Tag it uniquely per test: suites run concurrently.
pub fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("magneton-test-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// A default coordinator on the simulated H200.
pub fn mag() -> Magneton {
    Magneton::new(DeviceSpec::h200_sim())
}

/// Kernel record without a content sketch.
pub fn rec(label: &str, op: OpKind, energy_j: f64, time_us: f64) -> KernelRecord {
    rec_m(label, op, energy_j, time_us, vec![])
}

/// Kernel record carrying a content sketch.
pub fn rec_m(
    label: &str,
    op: OpKind,
    energy_j: f64,
    time_us: f64,
    moments: Vec<f64>,
) -> KernelRecord {
    KernelRecord {
        node: 0,
        op,
        label: label.to_string(),
        api: "api".into(),
        dispatch_key: op.name().to_string(),
        kernel: format!("k_{label}"),
        time_us,
        energy_j,
        avg_power_w: energy_j / (time_us * 1e-6),
        corr_id: 0,
        bb_trace: vec![],
        call_path: vec![Frame::py("serve")],
        moments,
    }
}

/// A power segment starting at `t0`.
pub fn seg_after(t0: f64, dur: f64, watts: f64) -> Segment {
    Segment { t_start_us: t0, t_end_us: t0 + dur, watts }
}

/// The serving-shaped op cycle shared by the stream/telemetry suites:
/// period 5, per-kind energies distinct enough that any mispairing
/// flags.
pub fn cycle_op(i: usize) -> (&'static str, OpKind, f64) {
    match i % 5 {
        0 => ("serve.proj", OpKind::MatMul, 0.30),
        1 => ("serve.scale", OpKind::Mul, 0.02),
        2 => ("serve.act", OpKind::Gelu, 0.05),
        3 => ("serve.out", OpKind::MatMul, 0.30),
        _ => ("serve.softmax", OpKind::Softmax, 0.08),
    }
}

/// Stream config for the cycle harness: tiled windows, NVML off.
pub fn stream_cfg(window_ops: usize) -> StreamConfig {
    StreamConfig {
        window_ops,
        hop_ops: window_ops,
        ring_cap: 128,
        nvml: None,
        ..Default::default()
    }
}

/// A kernel-level stream fault injected on side A.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Fault {
    /// The kernel never ran on side A.
    Drop,
    /// Side A emitted the kernel twice.
    Duplicate,
    /// A stray kernel ran on side A just before this one.
    Insert,
}

/// Drive `n` cycle ops through an auditor in lock-step, injecting
/// `faults` (position → kind, side A) and draining reports as they
/// emit. Returns the auditor (un-finished, so callers can inspect or
/// `finish` it) plus the drained reports.
pub fn run_cycle_pair_with_faults(
    cfg: StreamConfig,
    n: usize,
    faults: &[(usize, Fault)],
) -> (StreamAuditor, Vec<WindowReport>) {
    let mut aud = StreamAuditor::new(cfg, 90.0);
    let (mut ta, mut tb) = (0.0, 0.0);
    let mut reports = Vec::new();
    for i in 0..n {
        let (label, op, e) = cycle_op(i);
        let fault = faults.iter().find(|&&(at, _)| at == i).map(|&(_, f)| f);
        match fault {
            Some(Fault::Drop) => {}
            Some(Fault::Duplicate) => {
                for _ in 0..2 {
                    aud.ingest_a(&rec(label, op, e, 100.0), seg_after(ta, 100.0, e / 100e-6));
                    ta += 100.0;
                }
            }
            Some(Fault::Insert) => {
                aud.ingest_a(
                    &rec("fault.extra", OpKind::Mul, 0.01, 50.0),
                    seg_after(ta, 50.0, 0.01 / 50e-6),
                );
                ta += 50.0;
                aud.ingest_a(&rec(label, op, e, 100.0), seg_after(ta, 100.0, e / 100e-6));
                ta += 100.0;
            }
            None => {
                aud.ingest_a(&rec(label, op, e, 100.0), seg_after(ta, 100.0, e / 100e-6));
                ta += 100.0;
            }
        }
        aud.ingest_b(&rec(label, op, e, 100.0), seg_after(tb, 100.0, e / 100e-6));
        tb += 100.0;
        reports.append(&mut aud.take_emitted());
    }
    (aud, reports)
}

/// A serving stream pair side: side A's matmuls run at quality `eff`
/// (1.0 = optimal; lower burns extra energy at equal time).
pub fn mk_stream_run(label: &str, seed: u64, eff: f64, requests: usize) -> SysRun {
    let mut rng = Prng::new(seed);
    let spec = ServingStream { requests, batch: 64, d_model: 128 };
    SysRun::new(label, serving_dispatcher(eff), Env::new(), serving_stream_program(&mut rng, &spec))
}

/// A reader that meters every byte pulled through it — the probe the
/// session-index scalability test uses to prove the lazy header scan
/// reads O(files) bytes, not O(snapshot bytes). Share the counter cell
/// across readers and pass a factory closure to
/// `SessionIndex::scan_with`.
pub struct CountingReader<R> {
    inner: R,
    bytes: std::rc::Rc<std::cell::Cell<u64>>,
}

impl<R> CountingReader<R> {
    pub fn new(inner: R, bytes: std::rc::Rc<std::cell::Cell<u64>>) -> CountingReader<R> {
        CountingReader { inner, bytes }
    }
}

impl<R: std::io::Read> std::io::Read for CountingReader<R> {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        let n = self.inner.read(buf)?;
        self.bytes.set(self.bytes.get() + n as u64);
        Ok(n)
    }
}

/// Run a 1000-op cycle pair through a real auditor (optionally dropping
/// side A's event at `skip_at`) and wrap the summary as a fleet entry —
/// the input shape the divergence-correlation layer consumes.
pub fn audited_cycle_entry(name: &str, skip_at: Option<usize>) -> StreamFleetEntry {
    let faults: Vec<(usize, Fault)> = skip_at.map(|at| (at, Fault::Drop)).into_iter().collect();
    let (mut aud, _) = run_cycle_pair_with_faults(stream_cfg(100), 1000, &faults);
    let summary = aud.finish();
    let expected = usize::from(skip_at.is_some());
    assert_eq!(summary.resyncs, expected, "{name}: unexpected resync count");
    StreamFleetEntry { name: name.to_string(), summary, snapshot_errors: 0 }
}
