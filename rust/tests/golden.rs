//! Golden-file tests for the report renderers: each render is compared
//! byte-for-byte against a committed fixture under `tests/golden/`, so
//! an accidental format drift (column order, units, float precision)
//! shows up as a diff instead of silently changing operator-facing
//! output. Regenerate the fixtures with `BLESS=1 cargo test`.

use std::fs;
use std::path::PathBuf;

use magneton::analysis::{LintFinding, LintReport, Severity, TargetReport};
use magneton::coordinator::fleet::{
    DivergentPair, FleetDivergence, FleetReport, StreamFleetEntry, StreamFleetReport,
};
use magneton::detect::Side;
use magneton::analysis::diff::{MatchTier, RegionDelta, RegionVerdict, UnmatchedRegion};
use magneton::analysis::StaticDiffReport;
use magneton::report::{
    render_divergence, render_fleet, render_lint, render_ranking, render_session_diff,
    render_static_diff, render_stream, render_stream_fleet, render_window,
};
use magneton::stream::{StreamFinding, StreamSummary, WindowReport};
use magneton::telemetry::session::{LabelDelta, MatchVerdict, SessionDiff, WindowAlignment};
use magneton::telemetry::RankEntry;

/// Compare `rendered` against the committed fixture. `BLESS=1`
/// regenerates; a missing fixture is written (and flagged) so a fresh
/// renderer gets its baseline committed alongside.
fn check_golden(name: &str, rendered: &str) {
    let path = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/golden").join(name);
    let bless = std::env::var("BLESS").map(|v| v == "1").unwrap_or(false);
    if bless || !path.exists() {
        fs::create_dir_all(path.parent().unwrap()).unwrap();
        fs::write(&path, rendered).unwrap();
        if !bless {
            eprintln!("golden fixture {name} was missing; wrote it — commit it and re-run");
        }
        return;
    }
    let want = fs::read_to_string(&path).unwrap();
    assert_eq!(
        rendered, want,
        "render drifted from tests/golden/{name} (run with BLESS=1 to re-bless)"
    );
}

fn finding() -> StreamFinding {
    StreamFinding {
        label: "serve.proj".into(),
        ops: 4,
        energy_a_j: 0.75,
        energy_b_j: 0.5,
        time_a_us: 400.0,
        time_b_us: 400.0,
        diff_frac: 1.0 / 3.0,
        wasteful: Side::A,
        is_tradeoff: false,
    }
}

fn hot_summary() -> StreamSummary {
    StreamSummary {
        ops: 1000,
        windows: 10,
        energy_a_j: 12.5,
        energy_b_j: 10.0,
        time_a_us: 100_000.0,
        time_b_us: 100_000.0,
        wasted_j: 2.5,
        windows_flagged: 9,
        windows_quarantined: 0,
        top_labels: vec![("serve.proj".into(), 2.0, 9), ("serve.out".into(), 0.5, 3)],
        aligned: true,
        fingerprint_a: 0x00c0_ffee_1234_5678,
        fingerprint_b: 0x00c0_ffee_1234_5678,
        unpaired: 0,
        resyncs: 0,
        resync_skipped: 0,
        resync_log: vec![],
        content_mismatches: 0,
        reports_dropped: 0,
        peak_retained_segments: 128,
        peak_window_pairs: 100,
        peak_pending: 2,
    }
}

fn cool_summary() -> StreamSummary {
    StreamSummary {
        energy_a_j: 10.0,
        wasted_j: 0.0,
        windows_flagged: 0,
        top_labels: vec![],
        ..hot_summary()
    }
}

fn divergence() -> FleetDivergence {
    FleetDivergence {
        at_ops_min: 437,
        at_ops_max: 439,
        pairs: vec![
            DivergentPair { name: "serving-1".into(), at_ops: 438, resyncs: 2, skipped: 3 },
            DivergentPair { name: "serving-0".into(), at_ops: 437, resyncs: 1, skipped: 1 },
        ],
    }
}

#[test]
fn golden_render_window() {
    let w = WindowReport {
        seq: 3,
        pairs: 8,
        energy_a_j: 1.5,
        energy_b_j: 1.25,
        time_a_us: 800.0,
        time_b_us: 800.0,
        findings: vec![finding()],
        wasted_j: 0.25,
        aligned: true,
        resyncs: 0,
        quarantined: false,
        content_mismatches: 0,
        window_fp: 0xabc,
    };
    check_golden("window.txt", &render_window(&w));
}

#[test]
fn golden_render_stream() {
    check_golden("stream.txt", &render_stream("hot", &hot_summary()));
}

#[test]
fn golden_render_divergence() {
    check_golden("divergence.txt", &render_divergence(&divergence()));
}

#[test]
fn golden_render_ranking() {
    let ranking = vec![
        RankEntry {
            name: "hot".into(),
            wasted_j: 2.5,
            ops: 1000,
            windows: 10,
            windows_flagged: 9,
            resyncs: 0,
            aligned: true,
        },
        RankEntry {
            name: "cool".into(),
            wasted_j: 0.0,
            ops: 1000,
            windows: 10,
            windows_flagged: 0,
            resyncs: 1,
            aligned: false,
        },
    ];
    check_golden("ranking.txt", &render_ranking(&ranking));
}

#[test]
fn golden_render_fleet_empty() {
    let r = FleetReport {
        entries: vec![],
        total_wasted_j: 0.0,
        total_findings: 0,
        wall_time_us: 2500.0,
        workers: 8,
    };
    check_golden("fleet.txt", &render_fleet(&r));
}

#[test]
fn golden_render_stream_fleet() {
    let r = StreamFleetReport {
        entries: vec![
            StreamFleetEntry { name: "hot".into(), summary: hot_summary(), snapshot_errors: 0 },
            StreamFleetEntry { name: "cool".into(), summary: cool_summary(), snapshot_errors: 0 },
        ],
        total_wasted_j: 2.5,
        total_ops: 2000,
        divergences: vec![divergence()],
        snapshot_errors: 0,
        wall_time_us: 1500.0,
        workers: 4,
    };
    check_golden("stream_fleet.txt", &render_stream_fleet(&r));
}

#[test]
fn golden_render_lint() {
    let r = LintReport {
        targets: vec![
            TargetReport {
                name: "mini-x".into(),
                nodes: 42,
                static_j: 1.25,
                findings: vec![
                    LintFinding {
                        rule: "redundant-sync",
                        severity: Severity::Warn,
                        nodes: vec![7],
                        label: "dist.Join.barrier".into(),
                        est_wasted_j: 0.126,
                        suggestion: "drop the barrier or use an event wait".into(),
                        steps: vec![],
                    },
                    LintFinding {
                        rule: "unfused-matmul-add",
                        severity: Severity::Info,
                        nodes: vec![3, 4],
                        label: "attn.qkv_proj.matmul".into(),
                        est_wasted_j: 0.0005,
                        suggestion: "fuse into addmm".into(),
                        steps: vec![],
                    },
                ],
                error: None,
            },
            TargetReport {
                name: "mini-clean".into(),
                nodes: 10,
                static_j: 0.5,
                findings: vec![],
                error: None,
            },
            TargetReport {
                name: "mini-broken".into(),
                nodes: 3,
                static_j: 0.0,
                findings: vec![],
                error: Some("graph `g` has a cycle through node 1 (`a`)".into()),
            },
        ],
        total_findings: 2,
        total_est_wasted_j: 0.1265,
    };
    check_golden("lint.txt", &render_lint(&r));
}

#[test]
fn golden_render_static_diff() {
    let d = StaticDiffReport {
        target_a: "mini-stable-diffusion".into(),
        target_b: "case-c8".into(),
        nodes_a: 30,
        nodes_b: 30,
        total_a_j: 1.0,
        total_b_j: 1.5,
        regions: vec![
            RegionDelta {
                node_a: 6,
                node_b: 6,
                label_a: "sd.resnet.conv1".into(),
                label_b: "sd.resnet.conv1".into(),
                op: "conv2d",
                kernel_a: "ampere_tf32_s1688gemm_128x128".into(),
                kernel_b: "ampere_sgemm_fp32_128x128".into(),
                a_j: 0.4,
                b_j: 0.8,
                delta_j: 0.4,
                tier: MatchTier::Hash,
                verdict: RegionVerdict::BWasteful,
            },
            RegionDelta {
                node_a: 12,
                node_b: 14,
                label_a: "sd.attn.qkv".into(),
                label_b: "sd.attn.qkv".into(),
                op: "matmul",
                kernel_a: "ampere_tf32_s1688gemm_128x128".into(),
                kernel_b: "ampere_tf32_s1688gemm_128x128".into(),
                a_j: 0.25,
                b_j: 0.25,
                delta_j: 0.0,
                tier: MatchTier::Label,
                verdict: RegionVerdict::Close,
            },
        ],
        unmatched_a: vec![],
        unmatched_b: vec![UnmatchedRegion {
            node: 20,
            label: "sd.skip.concat".into(),
            op: "concat",
            cost_j: 0.05,
        }],
        error: None,
    };
    check_golden("static_diff.txt", &render_static_diff(&d));
}

#[test]
fn golden_render_session_diff() {
    let d = SessionDiff {
        session_a: "deploy-a".into(),
        session_b: "deploy-b (canary)".into(),
        verdict: MatchVerdict::Exact,
        notes: vec![
            "arrival processes differ (steady vs poisson@200Hz): idle-power timelines are not \
             comparable, per-op energies are"
                .into(),
        ],
        labels: vec![
            LabelDelta {
                label: "serve.proj".into(),
                ops_a: 100,
                ops_b: 100,
                energy_a_j: 1.0,
                energy_b_j: 1.5,
                delta_j: 0.5,
                delta_frac: 1.0 / 3.0,
                waste_a_j: 0.0,
                waste_b_j: 0.5,
            },
            LabelDelta {
                label: "serve.act".into(),
                ops_a: 100,
                ops_b: 120,
                energy_a_j: 0.5,
                energy_b_j: 0.5,
                delta_j: 0.0,
                delta_frac: 0.0,
                waste_a_j: 0.0,
                waste_b_j: 0.0,
            },
            LabelDelta {
                label: "serve.softmax".into(),
                ops_a: 100,
                ops_b: 100,
                energy_a_j: 0.5,
                energy_b_j: 0.25,
                delta_j: -0.25,
                delta_frac: 0.5,
                waste_a_j: 0.0,
                waste_b_j: 0.0,
            },
        ],
        new_labels: vec![("serve.extra".into(), 0.25)],
        vanished_labels: vec![("serve.old".into(), 0.125)],
        total_a_j: 2.0,
        total_b_j: 2.25,
        wasted_a_j: 0.0,
        wasted_b_j: 0.5,
        resyncs_a: 0,
        resyncs_b: 1,
        divergences_a: 0,
        divergences_b: 1,
        windows: WindowAlignment { aligned: 10, realigns: 1, skipped_a: 0, skipped_b: 1, forced: 0 },
        energy_threshold: 0.10,
    };
    check_golden("session_diff.txt", &render_session_diff(&d));
}
