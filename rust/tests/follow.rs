//! End-to-end tests of the live tail: a writer thread streaming
//! snapshots through a real `StreamAuditor` + rotating `SnapshotSink`
//! while a `Follower` tails the directory; online invariants raising
//! exactly one alarm per offending window; the bounded alarm publisher
//! under a stalled subscriber; and the sink surviving its directory
//! being removed mid-stream.

mod common;

use std::thread;
use std::time::Duration;

use common::{cycle_op, rec, seg_after, stream_cfg, tmp_dir};
use magneton::dash::{Invariant, Monitor};
use magneton::fingerprint::WorkloadSig;
use magneton::stream::{ResyncEvent, StreamAuditor};
use magneton::telemetry::follow::Follower;
use magneton::telemetry::{
    load_dir, snapshot_files, SessionHeader, SinkConfig, Snapshot, SnapshotSink,
};

fn resync(i: usize) -> Snapshot {
    Snapshot::Resync {
        pair: "p".into(),
        event: ResyncEvent { at_ops: i, skipped_a: 0, skipped_b: 1 },
    }
}

/// Drive `n` cycle ops through an auditor whose side A burns `infl`×
/// the energy at equal time (pure waste, no trade-off), with a sink
/// attached — the writer half of the live-tail tests.
fn run_wasteful_writer(dir: &std::path::Path, n: usize, rotate_bytes: u64) -> usize {
    let mut aud = StreamAuditor::new(stream_cfg(10), 90.0);
    let mut sig = WorkloadSig::new();
    for i in 0..5 {
        let (label, op, _) = cycle_op(i);
        sig.add(label, op.name());
    }
    aud.set_session_header(SessionHeader::new("follow-e2e", "test", "p", &sig, "steady", 7));
    let cfg = SinkConfig { max_snapshot_bytes: 0, rotate_bytes };
    aud.set_sink("p", SnapshotSink::new(dir, "p", cfg).unwrap());
    let (mut ta, mut tb) = (0.0, 0.0);
    for i in 0..n {
        let (label, op, e) = cycle_op(i);
        let ea = e * 1.3;
        aud.ingest_a(&rec(label, op, ea, 100.0), seg_after(ta, 100.0, ea / 100e-6));
        aud.ingest_b(&rec(label, op, e, 100.0), seg_after(tb, 100.0, e / 100e-6));
        ta += 100.0;
        tb += 100.0;
    }
    let _ = aud.finish();
    let errors = aud.sink_errors();
    assert_eq!(errors, 0, "the writer must persist cleanly");
    errors
}

/// The acceptance criterion: a follower tailing a live run (writer on
/// another thread) ends up bit-identical to a post-hoc replay of the
/// completed directory, across ≥2 file rotations.
#[test]
fn live_tail_is_bit_identical_to_posthoc_replay_across_rotations() {
    let dir = tmp_dir("follow-e2e");
    let wdir = dir.clone();
    let writer = thread::spawn(move || {
        run_wasteful_writer(&wdir, 300, 1500);
    });
    let mut follower = Follower::new(&dir);
    let mut live = 0usize;
    let mut quiet = 0u32;
    loop {
        let fresh = follower.poll().unwrap();
        live += fresh.len();
        if writer.is_finished() {
            if fresh.is_empty() {
                quiet += 1;
                if quiet >= 2 {
                    break;
                }
            } else {
                quiet = 0;
            }
        }
        thread::sleep(Duration::from_millis(1));
    }
    writer.join().unwrap();
    assert!(
        snapshot_files(&dir).unwrap().len() >= 3,
        "the run must have rotated at least twice"
    );
    let posthoc: Vec<String> = load_dir(&dir).unwrap().iter().map(Snapshot::to_line).collect();
    let followed: Vec<String> =
        follower.ordered_snapshots().iter().map(Snapshot::to_line).collect();
    assert!(!posthoc.is_empty());
    assert_eq!(followed, posthoc, "live tail must replay bit-identical to load_dir");
    assert_eq!(live, posthoc.len(), "every snapshot surfaced exactly once while live");
    let _ = std::fs::remove_dir_all(&dir);
}

/// A follower that keeps up with the writer survives the byte budget
/// dropping the oldest file: it re-anchors (counted) and retains the
/// snapshots it already consumed — strictly more than a post-hoc
/// replay of the pruned directory can recover.
#[test]
fn follower_survives_a_dropped_file_and_retains_its_snapshots() {
    let dir = tmp_dir("follow-drop");
    let cfg = SinkConfig { max_snapshot_bytes: 500, rotate_bytes: 150 };
    let mut sink = SnapshotSink::new(&dir, "p", cfg).unwrap();
    let mut follower = Follower::new(&dir);
    for i in 0..30 {
        sink.append(&resync(i)).unwrap();
        // polling after every append means every line is consumed
        // before the budget can drop its file
        follower.poll().unwrap();
    }
    assert!(sink.dropped_files >= 1, "the budget must have dropped a file");
    assert_eq!(follower.collected(), 30, "nothing the follower saw is lost");
    assert!(follower.reanchors >= 1, "dropped files must re-anchor, not error");
    let surviving: Vec<String> = load_dir(&dir).unwrap().iter().map(Snapshot::to_line).collect();
    assert!(surviving.len() < 30, "the directory itself did lose snapshots");
    let followed: Vec<String> =
        follower.ordered_snapshots().iter().map(Snapshot::to_line).collect();
    for line in &surviving {
        assert!(followed.contains(line), "follower must be a superset of the directory");
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// Online invariants over the live tail raise exactly one alarm per
/// offending window, and a post-hoc pass over the completed directory
/// raises the identical alarms — the checks are deterministic over the
/// snapshot stream, not over polling cadence.
#[test]
fn invariant_breach_alarms_exactly_once_per_offending_window() {
    let dir = tmp_dir("follow-alarms");
    run_wasteful_writer(&dir, 100, 2000);
    // side A wastes ~23% of every window — a 10% limit flags them all
    let invariants = vec![Invariant::MaxWindowWastePct(10.0)];
    let mut live = Monitor::new(invariants.clone());
    let mut follower = Follower::new(&dir);
    loop {
        let fresh = follower.poll().unwrap();
        if fresh.is_empty() {
            break;
        }
        for snap in &fresh {
            live.observe(snap);
        }
    }
    let snaps = load_dir(&dir).unwrap();
    let windows = snaps
        .iter()
        .filter(|s| matches!(s, Snapshot::Window { .. }))
        .count();
    assert!(windows >= 5);
    assert_eq!(live.alarms.len(), windows, "one alarm per offending window");
    // re-observing the whole stream raises nothing new
    for snap in &snaps {
        assert!(live.observe(snap).is_empty(), "re-observation must not re-alarm");
    }
    // a fresh post-hoc monitor reproduces the live alarms exactly
    let mut posthoc = Monitor::new(invariants);
    for snap in &snaps {
        posthoc.observe(snap);
    }
    assert_eq!(posthoc.alarms, live.alarms, "alarms are a function of the stream");
    // and they round-trip losslessly as snapshot lines
    for alarm in &live.alarms {
        let line = Snapshot::Alarm { alarm: alarm.clone() }.to_line();
        let Snapshot::Alarm { alarm: back } = Snapshot::parse_line(&line).unwrap() else {
            panic!("alarm line decoded as a different snapshot kind");
        };
        assert_eq!(&back, alarm);
        assert_eq!(Snapshot::Alarm { alarm: back }.to_line(), line, "lossless round-trip");
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// The bounded publisher under a stalled subscriber: drop-and-count,
/// never block the monitored stream.
#[test]
fn bounded_publisher_drops_and_counts_under_a_stalled_subscriber() {
    let mut p = magneton::dash::AlarmPublisher::new(3);
    let stalled = p.subscribe();
    let lines: Vec<String> = (0..12)
        .map(|i| {
            Snapshot::Alarm {
                alarm: magneton::telemetry::Alarm {
                    pair: "p".into(),
                    invariant: "max-window-waste-pct".into(),
                    seq: Some(i),
                    value: 40.0,
                    limit: 10.0,
                    detail: format!("window #{i}"),
                },
            }
            .to_line()
        })
        .collect();
    for line in &lines {
        p.publish(line);
    }
    assert_eq!(p.published, 12);
    assert_eq!(p.dropped, 9, "depth 3: nine lines must drop, counted");
    let got: Vec<String> = stalled.try_iter().collect();
    assert_eq!(got, lines[..3].to_vec(), "the subscriber keeps the oldest three");
}

/// The foregrounded `raw_write` bugfix, end to end: removing the sink
/// directory under a live auditor turns into counted sink errors —
/// never a panic unwinding the worker — and the audit itself finishes.
#[test]
fn sink_directory_removed_mid_stream_counts_errors_without_panicking() {
    let dir = tmp_dir("follow-rmdir");
    let mut aud = StreamAuditor::new(stream_cfg(5), 90.0);
    let cfg = SinkConfig { max_snapshot_bytes: 0, rotate_bytes: 300 };
    aud.set_sink("p", SnapshotSink::new(&dir, "p", cfg).unwrap());
    let (mut ta, mut tb) = (0.0, 0.0);
    for i in 0..20 {
        let (label, op, e) = cycle_op(i);
        aud.ingest_a(&rec(label, op, e, 100.0), seg_after(ta, 100.0, e / 100e-6));
        aud.ingest_b(&rec(label, op, e, 100.0), seg_after(tb, 100.0, e / 100e-6));
        ta += 100.0;
        tb += 100.0;
    }
    std::fs::remove_dir_all(&dir).unwrap();
    for i in 20..120 {
        let (label, op, e) = cycle_op(i);
        aud.ingest_a(&rec(label, op, e, 100.0), seg_after(ta, 100.0, e / 100e-6));
        aud.ingest_b(&rec(label, op, e, 100.0), seg_after(tb, 100.0, e / 100e-6));
        ta += 100.0;
        tb += 100.0;
    }
    let summary = aud.finish();
    assert_eq!(summary.ops, 120, "the audit itself must be unaffected");
    assert!(
        aud.sink_errors() > 0,
        "writes into the removed directory must surface as counted errors"
    );
}
