//! Cross-module property tests on coordinator invariants: symmetry,
//! determinism, energy conservation, and region sanity of the full
//! differential pipeline — plus streaming-resync robustness under
//! random fault sequences and arrival-process statistics.

mod common;

use common::{mag, run_cycle_pair_with_faults, stream_cfg, Fault};
use magneton::cases;
use magneton::detect::Side;
use magneton::util::{fnv1a, Prng};
use magneton::workload::ArrivalProcess;

/// Swapping the two systems must swap the finding sides but preserve
/// detection, diffs, and root causes.
#[test]
fn prop_audit_is_symmetric() {
    let m = mag();
    for id in ["c8", "c10", "c16"] {
        let s = cases::by_id(id).unwrap();
        let mut r1 = Prng::new(500);
        let (a, b) = (s.build)(&mut r1);
        let fwd = m.audit(&a, &b);
        let mut r2 = Prng::new(500);
        let (a2, b2) = (s.build)(&mut r2);
        let rev = m.audit(&b2, &a2);
        assert_eq!(fwd.detected(), rev.detected(), "{id}: detection not symmetric");
        assert!(
            (fwd.e2e_diff_frac - rev.e2e_diff_frac).abs() < 1e-9,
            "{id}: e2e diff not symmetric"
        );
        if let (Some(f), Some(r)) = (fwd.findings.first(), rev.findings.first()) {
            assert_ne!(f.wasteful == Side::A, r.wasteful == Side::A, "{id}: side must flip");
            assert!((f.diff_frac - r.diff_frac).abs() < 1e-6, "{id}: diff must match");
        }
    }
}

/// The pipeline is deterministic given the workload seed.
#[test]
fn prop_audit_is_deterministic() {
    let m = mag();
    let render = |seed: u64| {
        let s = cases::by_id("c12").unwrap();
        let mut rng = Prng::new(seed);
        let (a, b) = (s.build)(&mut rng);
        let out = m.audit(&a, &b);
        out.diagnoses
            .iter()
            .map(|(f, d)| format!("{}|{}", f.summary(), d.render()))
            .collect::<Vec<_>>()
            .join("\n")
    };
    assert_eq!(render(7), render(7));
    assert!(!render(7).is_empty());
}

/// Kernel-record energy, power-trace integration, and the trace-buffer
/// attribution must all agree (three views of the same ground truth).
#[test]
fn prop_energy_accounting_consistent() {
    let m = mag();
    let mut rng = Prng::new(321);
    for s in cases::known_cases().into_iter().take(6) {
        let (a, _) = (s.build)(&mut rng);
        let arts = m.run_side(&a);
        let from_records: f64 = arts.records.iter().map(|r| r.energy_j).sum();
        assert!((from_records - arts.total_energy_j).abs() < 1e-12);
        let from_trace = arts.trace.kernel_energy_j();
        assert!(
            (from_trace - arts.total_energy_j).abs() / arts.total_energy_j.max(1e-12) < 1e-9,
            "{}: trace attribution diverges",
            s.id
        );
        let from_power = arts.power.total_energy();
        let rel = (from_power - arts.total_energy_j).abs() / arts.total_energy_j.max(1e-12);
        assert!(rel < 0.05, "{}: power integral diverges {rel}", s.id);
    }
}

/// Matched regions only reference valid nodes and never claim energy
/// that the runs did not spend.
#[test]
fn prop_regions_are_sane() {
    let m = mag();
    let mut rng = Prng::new(99);
    for s in cases::known_cases().into_iter().take(8) {
        let (a, b) = (s.build)(&mut rng);
        let out = m.audit(&a, &b);
        for region in &out.regions {
            assert!(region.a_nodes.iter().all(|&n| n < out.a.graph.len()), "{}", s.id);
            assert!(region.b_nodes.iter().all(|&n| n < out.b.graph.len()), "{}", s.id);
        }
        for f in &out.findings {
            assert!(f.energy_a_j <= out.a.total_energy_j * (1.0 + 1e-9), "{}", s.id);
            assert!(f.energy_b_j <= out.b.total_energy_j * (1.0 + 1e-9), "{}", s.id);
            assert!((0.0..=1.0).contains(&f.diff_frac), "{}", s.id);
        }
    }
}

/// A stricter detection threshold can only shrink the finding set.
#[test]
fn prop_threshold_monotone() {
    let s = cases::by_id("c5").unwrap();
    let mut rng = Prng::new(44);
    let (a, b) = (s.build)(&mut rng);
    let mut counts = Vec::new();
    for thr in [0.02, 0.05, 0.10, 0.30, 0.60] {
        let mut m = mag();
        m.cfg.energy_threshold = thr;
        counts.push(m.audit(&a, &b).findings.len());
    }
    assert!(counts.windows(2).all(|w| w[0] >= w[1]), "not monotone: {counts:?}");
    assert!(counts[0] > 0, "loosest threshold finds nothing");
}

/// Auditing a system against itself is always clean, for every case
/// builder's wasteful side.
#[test]
fn prop_self_audit_is_clean() {
    let m = mag();
    for id in ["c3", "c7", "c13"] {
        let s = cases::by_id(id).unwrap();
        let mut r1 = Prng::new(61);
        let mut r2 = Prng::new(61);
        let (a1, _) = (s.build)(&mut r1);
        let (a2, _) = (s.build)(&mut r2);
        let out = m.audit(&a1, &a2);
        assert!(!out.detected(), "{id}: self-audit flagged waste");
        assert!(out.e2e_diff_frac < 1e-6, "{id}: self diff {}", out.e2e_diff_frac);
    }
}

/// Resync robustness: seeded random drop/insert/duplicate kernel fault
/// sequences injected into a 1000-op stream pair must always
/// re-converge — every fault recovered by exactly one resync,
/// `windows_quarantined` bounded by the fault count, zero spurious
/// findings anywhere (the two sides spend identical energy on every
/// matched pair), and clean aligned windows after the last fault.
#[test]
fn prop_resync_reconverges_under_random_fault_sequences() {
    let kinds = [Fault::Drop, Fault::Duplicate, Fault::Insert];
    let mut rng = Prng::new(0x5eed_fa17);
    for case in 0..6 {
        // 1..=4 faults at random positions, spaced ≥ 50 ops so each
        // divergence resolves before the next one begins
        let n_faults = 1 + rng.below(4);
        let mut faults = Vec::new();
        let mut at = 60 + rng.below(60);
        for _ in 0..n_faults {
            if at >= 900 {
                break;
            }
            faults.push((at, kinds[rng.below(kinds.len())]));
            at += 50 + rng.below(150);
        }
        let (mut aud, mut reports) = run_cycle_pair_with_faults(stream_cfg(100), 1000, &faults);
        let s = aud.finish();
        reports.append(&mut aud.take_emitted());

        assert_eq!(
            s.resyncs,
            faults.len(),
            "case {case} ({faults:?}): every fault must cost exactly one resync"
        );
        assert_eq!(s.resync_skipped, faults.len(), "case {case}: one skip per fault");
        assert!(
            s.windows_quarantined <= faults.len(),
            "case {case}: {} quarantined > {} faults",
            s.windows_quarantined,
            faults.len()
        );
        assert!(s.windows_quarantined >= 1, "case {case}: a fault must quarantine its window");
        // both sides spend identical energy on every matched pair, so
        // ANY finding is spurious — recovered pairing must stay clean
        assert_eq!(s.windows_flagged, 0, "case {case}: spurious findings after resync");
        assert_eq!(s.wasted_j, 0.0, "case {case}");
        // re-convergence: the matched histories end identical
        assert_eq!(s.fingerprint_a, s.fingerprint_b, "case {case}");
        // every window after the last fault is aligned and clean
        let last_fault = faults.last().unwrap().0;
        let window_ops = 100;
        for r in &reports {
            assert!(r.findings.is_empty(), "case {case}: window #{} flagged", r.seq);
            if r.seq * window_ops > last_fault + window_ops {
                assert!(r.aligned, "case {case}: window #{} misaligned after last fault", r.seq);
                assert!(!r.quarantined, "case {case}: window #{} quarantined", r.seq);
            }
        }
    }
}

/// Arrival statistics: empirical inter-arrival means match the
/// configured rates for Poisson and bursty traffic, steady never
/// idles, and the gap sequences are bit-identical for equal seeds.
#[test]
fn prop_arrival_means_match_configured_rates() {
    let mut rng = Prng::new(0xa441);
    // steady: no idle gaps, ever
    for i in 1..200 {
        assert_eq!(ArrivalProcess::BackToBack.gap_us(&mut rng, i), 0.0);
    }
    // Poisson at rate r: mean gap within 5 % of 1e6/r, for several rates
    for rate_hz in [50.0, 200.0, 1000.0] {
        let p = ArrivalProcess::Poisson { rate_hz };
        let n = 30_000;
        let mut sum = 0.0;
        for i in 1..=n {
            let g = p.gap_us(&mut rng, i);
            assert!(g > 0.0);
            sum += g;
        }
        let mean = sum / n as f64;
        let want = 1e6 / rate_hz;
        assert!(
            (mean - want).abs() / want < 0.05,
            "poisson@{rate_hz}: empirical mean {mean} vs {want}"
        );
    }
    // bursty: idles only at burst boundaries, and the lull mean tracks
    // the configured lull rate
    let bursty = ArrivalProcess::Bursty { burst_len: 8, lull_hz: 100.0 };
    let mut lulls = 0usize;
    let mut lull_sum = 0.0;
    for i in 1..=40_000 {
        let g = bursty.gap_us(&mut rng, i);
        if i % 8 == 0 {
            assert!(g > 0.0, "burst boundary {i} must idle");
            lulls += 1;
            lull_sum += g;
        } else {
            assert_eq!(g, 0.0, "mid-burst {i} must not idle");
        }
    }
    let lull_mean = lull_sum / lulls as f64;
    assert!(
        (lull_mean - 10_000.0).abs() / 10_000.0 < 0.05,
        "bursty lull mean {lull_mean} vs 10000"
    );
}

/// The per-pair arrival rng fork (`arrival_seed ^ fnv1a(pair name)`,
/// the scheme `StreamFleet` uses) yields gap sequences that are
/// bit-identical for equal seeds no matter how many workers process
/// the pairs or in what order — the property that makes fleet results
/// worker-count-independent under sampled arrivals.
#[test]
fn prop_arrival_sequences_bit_identical_across_worker_orders() {
    let arrival = ArrivalProcess::Poisson { rate_hz: 500.0 };
    let seed = 0x6d61_676eu64;
    let pairs = ["serving-0", "serving-1", "serving-2", "serving-3"];
    let gaps_for = |name: &str| -> Vec<u64> {
        let mut rng = Prng::new(seed ^ fnv1a(name.bytes()));
        (1..=200).map(|i| arrival.gap_us(&mut rng, i).to_bits()).collect()
    };
    // "one worker": pairs processed in submission order
    let serial: Vec<Vec<u64>> = pairs.iter().map(|p| gaps_for(p)).collect();
    // "many workers": pairs processed in reverse (any interleaving —
    // each pair's rng is independent of processing order)
    let reversed: Vec<Vec<u64>> = pairs.iter().rev().map(|p| gaps_for(p)).collect();
    for (i, p) in pairs.iter().enumerate() {
        assert_eq!(
            serial[i],
            reversed[pairs.len() - 1 - i],
            "{p}: gap sequence depends on processing order"
        );
    }
    // distinct pairs draw distinct sequences (no accidental sharing)
    assert_ne!(serial[0], serial[1]);
    // and equal seeds reproduce bit-for-bit across runs
    assert_eq!(gaps_for("serving-0"), serial[0]);
}
