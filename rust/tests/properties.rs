//! Cross-module property tests on coordinator invariants: symmetry,
//! determinism, energy conservation, and region sanity of the full
//! differential pipeline.

use magneton::cases;
use magneton::coordinator::Magneton;
use magneton::detect::Side;
use magneton::energy::DeviceSpec;
use magneton::util::Prng;

fn mag() -> Magneton {
    Magneton::new(DeviceSpec::h200_sim())
}

/// Swapping the two systems must swap the finding sides but preserve
/// detection, diffs, and root causes.
#[test]
fn prop_audit_is_symmetric() {
    let m = mag();
    for id in ["c8", "c10", "c16"] {
        let s = cases::by_id(id).unwrap();
        let mut r1 = Prng::new(500);
        let (a, b) = (s.build)(&mut r1);
        let fwd = m.audit(&a, &b);
        let mut r2 = Prng::new(500);
        let (a2, b2) = (s.build)(&mut r2);
        let rev = m.audit(&b2, &a2);
        assert_eq!(fwd.detected(), rev.detected(), "{id}: detection not symmetric");
        assert!(
            (fwd.e2e_diff_frac - rev.e2e_diff_frac).abs() < 1e-9,
            "{id}: e2e diff not symmetric"
        );
        if let (Some(f), Some(r)) = (fwd.findings.first(), rev.findings.first()) {
            assert_ne!(f.wasteful == Side::A, r.wasteful == Side::A, "{id}: side must flip");
            assert!((f.diff_frac - r.diff_frac).abs() < 1e-6, "{id}: diff must match");
        }
    }
}

/// The pipeline is deterministic given the workload seed.
#[test]
fn prop_audit_is_deterministic() {
    let m = mag();
    let render = |seed: u64| {
        let s = cases::by_id("c12").unwrap();
        let mut rng = Prng::new(seed);
        let (a, b) = (s.build)(&mut rng);
        let out = m.audit(&a, &b);
        out.diagnoses
            .iter()
            .map(|(f, d)| format!("{}|{}", f.summary(), d.render()))
            .collect::<Vec<_>>()
            .join("\n")
    };
    assert_eq!(render(7), render(7));
    assert!(!render(7).is_empty());
}

/// Kernel-record energy, power-trace integration, and the trace-buffer
/// attribution must all agree (three views of the same ground truth).
#[test]
fn prop_energy_accounting_consistent() {
    let m = mag();
    let mut rng = Prng::new(321);
    for s in cases::known_cases().into_iter().take(6) {
        let (a, _) = (s.build)(&mut rng);
        let arts = m.run_side(&a);
        let from_records: f64 = arts.records.iter().map(|r| r.energy_j).sum();
        assert!((from_records - arts.total_energy_j).abs() < 1e-12);
        let from_trace = arts.trace.kernel_energy_j();
        assert!(
            (from_trace - arts.total_energy_j).abs() / arts.total_energy_j.max(1e-12) < 1e-9,
            "{}: trace attribution diverges",
            s.id
        );
        let from_power = arts.power.total_energy();
        let rel = (from_power - arts.total_energy_j).abs() / arts.total_energy_j.max(1e-12);
        assert!(rel < 0.05, "{}: power integral diverges {rel}", s.id);
    }
}

/// Matched regions only reference valid nodes and never claim energy
/// that the runs did not spend.
#[test]
fn prop_regions_are_sane() {
    let m = mag();
    let mut rng = Prng::new(99);
    for s in cases::known_cases().into_iter().take(8) {
        let (a, b) = (s.build)(&mut rng);
        let out = m.audit(&a, &b);
        for region in &out.regions {
            assert!(region.a_nodes.iter().all(|&n| n < out.a.graph.len()), "{}", s.id);
            assert!(region.b_nodes.iter().all(|&n| n < out.b.graph.len()), "{}", s.id);
        }
        for f in &out.findings {
            assert!(f.energy_a_j <= out.a.total_energy_j * (1.0 + 1e-9), "{}", s.id);
            assert!(f.energy_b_j <= out.b.total_energy_j * (1.0 + 1e-9), "{}", s.id);
            assert!((0.0..=1.0).contains(&f.diff_frac), "{}", s.id);
        }
    }
}

/// A stricter detection threshold can only shrink the finding set.
#[test]
fn prop_threshold_monotone() {
    let s = cases::by_id("c5").unwrap();
    let mut rng = Prng::new(44);
    let (a, b) = (s.build)(&mut rng);
    let mut counts = Vec::new();
    for thr in [0.02, 0.05, 0.10, 0.30, 0.60] {
        let mut m = mag();
        m.cfg.energy_threshold = thr;
        counts.push(m.audit(&a, &b).findings.len());
    }
    assert!(counts.windows(2).all(|w| w[0] >= w[1]), "not monotone: {counts:?}");
    assert!(counts[0] > 0, "loosest threshold finds nothing");
}

/// Auditing a system against itself is always clean, for every case
/// builder's wasteful side.
#[test]
fn prop_self_audit_is_clean() {
    let m = mag();
    for id in ["c3", "c7", "c13"] {
        let s = cases::by_id(id).unwrap();
        let mut r1 = Prng::new(61);
        let mut r2 = Prng::new(61);
        let (a1, _) = (s.build)(&mut r1);
        let (a2, _) = (s.build)(&mut r2);
        let out = m.audit(&a1, &a2);
        assert!(!out.detected(), "{id}: self-audit flagged waste");
        assert!(out.e2e_diff_frac < 1e-6, "{id}: self diff {}", out.e2e_diff_frac);
    }
}
