//! Shard-equivalence acceptance for multi-process ingest: producer
//! shards persisting slices of one pair fleet must merge back into a
//! session **bit-for-bit identical** to the unsharded single-process
//! run — for any shard count, any merge order, and any permutation of
//! the shard directories — and the merge must survive (and account
//! for) damaged shards: torn trailing fragments, dropped rotation
//! files, and operator mistakes like passing the same shard twice.

mod common;

use std::path::{Path, PathBuf};

use common::{mk_stream_run, tmp_dir};
use magneton::coordinator::fleet::StreamFleet;
use magneton::energy::DeviceSpec;
use magneton::telemetry::merge::{merge_shards, MergeConfig};
use magneton::telemetry::{Replay, SinkConfig};

const SESSION: &str = "shard-equivalence";
const SEED: u64 = 0x90;
const WINDOW_OPS: usize = 40;

/// Run the fleet slice `[lo, hi)` of a `total`-pair fleet into `dir`.
/// `shard: None` is the unsharded reference (which must cover the whole
/// fleet); `Some((idx, count))` stamps shard identity and fleet-global
/// pair indices. Per-pair seeds and specs depend only on the global
/// pair index, exactly like `magneton stream --shard`.
fn run_slice(
    dir: &Path,
    lo: usize,
    hi: usize,
    shard: Option<(usize, usize)>,
    requests: usize,
    sink_cfg: SinkConfig,
) {
    let mut fleet = StreamFleet::new(DeviceSpec::h200_sim());
    fleet.workers = 2;
    fleet.cfg.window_ops = WINDOW_OPS;
    fleet.cfg.hop_ops = WINDOW_OPS;
    fleet.cfg.ring_cap = 64;
    fleet.snapshot_dir = Some(dir.to_path_buf());
    fleet.session_id = Some(SESSION.to_string());
    fleet.deploy_tag = "v1".into();
    fleet.sink_cfg = sink_cfg;
    if let Some((idx, count)) = shard {
        fleet.pair_index_base = lo;
        fleet.shard_id = format!("host-{idx}");
        fleet.shard_index = idx;
        fleet.shard_count = count;
    }
    for i in lo..hi {
        let eff = if i % 2 == 0 { 0.6 } else { 1.0 };
        fleet.add_pair(
            &format!("serving-{i}"),
            mk_stream_run("sys-a", SEED + 1 + i as u64, eff, requests),
            mk_stream_run("sys-b", SEED + 1 + i as u64, 1.0, requests),
        );
    }
    let r = fleet.run();
    assert_eq!(r.snapshot_errors, 0, "snapshot writes must succeed");
}

/// Split `total` pairs into `count` shard directories under `base`,
/// mirroring the `--shard k/M` slice arithmetic (ceil division).
fn run_shards(base: &Path, total: usize, count: usize, requests: usize) -> Vec<PathBuf> {
    let per_shard = total.div_ceil(count);
    let mut dirs = Vec::new();
    for idx in 0..count {
        let (lo, hi) = ((idx * per_shard).min(total), ((idx + 1) * per_shard).min(total));
        assert!(lo < hi, "test fleet must populate every shard");
        let dir = base.join(format!("m{count}-s{idx}"));
        run_slice(&dir, lo, hi, Some((idx, count)), requests, never_rotate());
        dirs.push(dir);
    }
    dirs
}

fn never_rotate() -> SinkConfig {
    SinkConfig { max_snapshot_bytes: 0, rotate_bytes: 0 }
}

fn merge_cfg() -> MergeConfig {
    // reproduce the run's own correlation: its effective window is
    // cfg.window_ops (correlate_window_ops was left 0)
    MergeConfig { correlate_window_ops: WINDOW_OPS, correlate_min: 2, allow_partial: false }
}

/// Every `.ndjson` file of `dir` as `(file name, bytes)`, sorted.
fn dir_bytes(dir: &Path) -> Vec<(String, Vec<u8>)> {
    let mut files: Vec<(String, Vec<u8>)> = std::fs::read_dir(dir)
        .unwrap()
        .map(|e| {
            let p = e.unwrap().path();
            (p.file_name().unwrap().to_string_lossy().into_owned(), std::fs::read(&p).unwrap())
        })
        .collect();
    files.sort();
    files
}

fn assert_same_files(got: &Path, want: &Path, what: &str) {
    let (got, want) = (dir_bytes(got), dir_bytes(want));
    assert_eq!(
        got.iter().map(|(n, _)| n.as_str()).collect::<Vec<_>>(),
        want.iter().map(|(n, _)| n.as_str()).collect::<Vec<_>>(),
        "{what}: file sets differ"
    );
    for ((name, g), (_, w)) in got.iter().zip(want.iter()) {
        assert!(g == w, "{what}: {name} is not byte-identical to the unsharded run's file");
    }
}

/// The tentpole property: merging M producer shards reproduces the
/// unsharded run bit-for-bit — same ranking (names and `to_bits`
/// ledgers), same totals, and a persisted merged directory whose every
/// file is byte-identical to the single-process directory — for shard
/// counts 1, 2, 4, and 8, and regardless of the order the shard
/// directories are passed in.
#[test]
fn merged_shards_reproduce_the_unsharded_run_bit_for_bit() {
    let base = tmp_dir("merge-bitident");
    let total = 8;
    let unsharded = base.join("unsharded");
    run_slice(&unsharded, 0, total, None, 10, never_rotate());
    let reference = Replay::load(&unsharded).unwrap();
    assert_eq!(reference.rankings.len(), 1);
    let ref_ranking = &reference.rankings[0];
    assert_eq!(ref_ranking.len(), total);

    let mut ledger_snapshots: Vec<Vec<u64>> = Vec::new();
    for count in [1usize, 2, 4, 8] {
        let dirs = run_shards(&base, total, count, 10);
        let m = merge_shards(&dirs, &merge_cfg()).unwrap();
        assert_eq!(m.session_id, SESSION);
        assert_eq!(m.shards.len(), count);
        assert_eq!(m.torn_fragments + m.missing_rotations, 0);

        // ranking: names, order, and waste ledgers all bit-equal
        assert_eq!(m.ranking.len(), ref_ranking.len(), "{count} shards");
        for (got, want) in m.ranking.iter().zip(ref_ranking.iter()) {
            assert_eq!(got.name, want.name, "{count} shards");
            assert_eq!(
                got.wasted_j.to_bits(),
                want.wasted_j.to_bits(),
                "{count} shards: {} wasted_j",
                got.name
            );
            assert_eq!(got.ops, want.ops, "{count} shards: {}", got.name);
            assert_eq!(got.windows, want.windows, "{count} shards: {}", got.name);
            assert_eq!(got.windows_flagged, want.windows_flagged, "{count} shards: {}", got.name);
        }
        let ref_total: f64 = ref_ranking.iter().map(|e| e.wasted_j).sum();
        assert_eq!(m.total_wasted_j.to_bits(), ref_total.to_bits(), "{count} shards: total fold");

        // the persisted merged directory is file-for-file, byte-for-byte
        // the unsharded directory
        let out = base.join(format!("merged-{count}"));
        m.persist(&out).unwrap();
        assert_same_files(&out, &unsharded, &format!("{count}-shard merge"));
        let replayed = Replay::load(&out).unwrap();
        assert_eq!(replayed.verify_ranking(), Ok(total));

        // shard-order invariance: reversed directory list, same bits
        let mut reversed = dirs.clone();
        reversed.reverse();
        let m2 = merge_shards(&reversed, &merge_cfg()).unwrap();
        let out2 = base.join(format!("merged-{count}-rev"));
        m2.persist(&out2).unwrap();
        assert_same_files(&out2, &unsharded, &format!("{count}-shard reversed merge"));

        // the combined per-label ledger is permutation-invariant too
        let bits = |m: &magneton::telemetry::merge::MergedSession| -> Vec<u64> {
            m.fleet_ledger
                .iter()
                .flat_map(|l| {
                    [
                        l.ops as u64,
                        l.energy_a_j.to_bits(),
                        l.energy_b_j.to_bits(),
                        l.time_a_us.to_bits(),
                        l.time_b_us.to_bits(),
                    ]
                })
                .collect()
        };
        assert_eq!(bits(&m), bits(&m2), "{count} shards: fleet ledger fold order leaked");
        ledger_snapshots.push(bits(&m));
    }
    // ... and invariant across shard *counts*: 1 == 2 == 4 == 8
    for w in ledger_snapshots.windows(2) {
        assert_eq!(w[0], w[1], "fleet ledger differs across shard counts");
    }
    let _ = std::fs::remove_dir_all(&base);
}

/// A producer killed mid-append leaves a torn trailing fragment. The
/// merge skips the fragment, counts it in the damage inventory, and
/// keeps every undamaged pair's attribution bit-identical.
#[test]
fn torn_trailing_fragment_is_counted_and_contained() {
    let base = tmp_dir("merge-torn");
    let total = 4;
    let dirs = run_shards(&base, total, 2, 10);
    let clean = merge_shards(&dirs, &merge_cfg()).unwrap();

    // tear the last line of shard 0's first pair file (drop the final
    // newline plus a few bytes, leaving an incomplete JSON fragment)
    let victim = dirs[0].join("pair-000-serving-0-000000.ndjson");
    let bytes = std::fs::read(&victim).unwrap();
    assert!(bytes.ends_with(b"\n"));
    std::fs::write(&victim, &bytes[..bytes.len() - 4]).unwrap();

    let m = merge_shards(&dirs, &merge_cfg()).unwrap();
    assert_eq!(m.torn_fragments, 1, "the torn fragment must be counted, not fatal");
    assert_eq!(m.shards[0].torn_fragments, 1);
    assert_eq!(m.shards[1].torn_fragments, 0);
    // every pair except the damaged one keeps bit-identical attribution
    for want in clean.ranking.iter().filter(|e| e.name != "serving-0") {
        let got = m
            .ranking
            .iter()
            .find(|e| e.name == want.name)
            .unwrap_or_else(|| panic!("{} lost by an unrelated torn fragment", want.name));
        assert_eq!(got.wasted_j.to_bits(), want.wasted_j.to_bits(), "{}", want.name);
        assert_eq!(got.ops, want.ops, "{}", want.name);
    }
    let _ = std::fs::remove_dir_all(&base);
}

/// A rotation file lost from the *middle* of a pair's series is damage
/// (rotation only ever drops oldest files): the merge counts it and
/// the other pairs' attribution is unaffected.
#[test]
fn missing_middle_rotation_file_is_counted_as_damage() {
    let base = tmp_dir("merge-hole");
    let total = 4;
    let per_shard = 2;
    // small rotate budget so every pair's series spans several files
    let mut dirs = Vec::new();
    for idx in 0..2 {
        let (lo, hi) = (idx * per_shard, (idx + 1) * per_shard);
        let dir = base.join(format!("s{idx}"));
        run_slice(
            &dir,
            lo,
            hi,
            Some((idx, 2)),
            40,
            SinkConfig { max_snapshot_bytes: 0, rotate_bytes: 512 },
        );
        dirs.push(dir);
    }
    let clean = merge_shards(&dirs, &merge_cfg()).unwrap();
    assert_eq!(clean.missing_rotations, 0);

    // drop a middle rotation file of shard 1's first pair
    let series: Vec<PathBuf> = std::fs::read_dir(&dirs[1])
        .unwrap()
        .map(|e| e.unwrap().path())
        .filter(|p| {
            p.file_name().unwrap().to_string_lossy().starts_with("pair-002-serving-2-")
        })
        .collect();
    assert!(series.len() >= 3, "need a rotated series to drop from, got {}", series.len());
    let mut sorted = series.clone();
    sorted.sort();
    std::fs::remove_file(&sorted[1]).unwrap();

    let m = merge_shards(&dirs, &merge_cfg()).unwrap();
    assert_eq!(m.missing_rotations, 1, "the interior hole must be counted");
    assert_eq!(m.shards[1].missing_rotations, 1);
    assert_eq!(m.shards[0].missing_rotations, 0);
    for want in clean.ranking.iter().filter(|e| e.name != "serving-2") {
        let got = m
            .ranking
            .iter()
            .find(|e| e.name == want.name)
            .unwrap_or_else(|| panic!("{} lost by an unrelated missing file", want.name));
        assert_eq!(got.wasted_j.to_bits(), want.wasted_j.to_bits(), "{}", want.name);
    }
    let _ = std::fs::remove_dir_all(&base);
}

/// Operator mistakes are refused with reasoned diagnostics: the same
/// shard directory twice, and an incomplete shard set without
/// `--partial-ok` — while a deliberate partial merge keeps the present
/// shards' attribution exact.
#[test]
fn duplicate_and_incomplete_shard_sets_are_refused() {
    let base = tmp_dir("merge-dup");
    let total = 4;
    let dirs = run_shards(&base, total, 2, 10);
    let clean = merge_shards(&dirs, &merge_cfg()).unwrap();

    let err = merge_shards(&[dirs[0].clone(), dirs[0].clone(), dirs[1].clone()], &merge_cfg())
        .unwrap_err();
    assert!(err.to_string().contains("given twice"), "{err}");

    let err = merge_shards(&dirs[..1], &merge_cfg()).unwrap_err();
    assert!(err.to_string().contains("incomplete shard set"), "{err}");
    assert!(err.to_string().contains("--partial-ok"), "{err}");

    let partial = MergeConfig { allow_partial: true, ..merge_cfg() };
    let m = merge_shards(&dirs[..1], &partial).unwrap();
    assert_eq!(m.ranking.len(), 2, "shard 0 holds pairs 0..2");
    for got in &m.ranking {
        let want = clean.ranking.iter().find(|e| e.name == got.name).unwrap();
        assert_eq!(got.wasted_j.to_bits(), want.wasted_j.to_bits(), "{}", got.name);
    }
    let _ = std::fs::remove_dir_all(&base);
}
