//! Cross-session differential replay acceptance: two persisted
//! sessions of the same workload — one with an injected per-label
//! energy regression — must diff to a report that ranks the regressed
//! labels first and trips the regression gate; the diff must be
//! bit-reproducible across runs and worker counts; and sessions with
//! non-matching workload fingerprints must be refused with a reasoned
//! diagnostic rather than compared.

mod common;

use std::path::PathBuf;

use common::{mk_stream_run, tmp_dir, CountingReader};
use magneton::coordinator::fleet::StreamFleet;
use magneton::energy::DeviceSpec;
use magneton::report::render_session_diff;
use magneton::telemetry::session::{
    diff_sessions, match_sessions, DiffConfig, MatchMode, MatchVerdict, SessionIndex, SessionInfo,
};

/// Persist one session: a 2-pair streaming fleet over the serving
/// workload, side A at quality `eff`, into `dir`.
fn persist_session(dir: &PathBuf, id: &str, eff: f64, workers: usize, requests: usize) {
    let mut fleet = StreamFleet::new(DeviceSpec::h200_sim());
    fleet.workers = workers;
    fleet.cfg.window_ops = 40;
    fleet.cfg.hop_ops = 40;
    fleet.cfg.ring_cap = 64;
    fleet.snapshot_dir = Some(dir.clone());
    fleet.session_id = Some(id.to_string());
    fleet.deploy_tag = "accept".into();
    for i in 0..2 {
        fleet.add_pair(
            &format!("serving-{i}"),
            mk_stream_run("sys-a", 90 + i as u64, eff, requests),
            mk_stream_run("sys-b", 90 + i as u64, 1.0, requests),
        );
    }
    let r = fleet.run();
    assert_eq!(r.snapshot_errors, 0, "{id}: snapshot writes must succeed");
}

/// The tentpole acceptance path: deploy A is clean, deploy B ships a
/// matmul-kernel regression (side A at 0.6 efficiency). `diff`
/// must rank the regressed matmul labels first, gate non-zero, and be
/// bit-reproducible — including against a session persisted with a
/// different worker count.
#[test]
fn diff_ranks_injected_regression_first_and_reproduces_bitwise() {
    let dir_a = tmp_dir("session-a");
    let dir_b = tmp_dir("session-b");
    let dir_b2 = tmp_dir("session-b2");
    persist_session(&dir_a, "deploy-a", 1.0, 2, 24);
    persist_session(&dir_b, "deploy-b", 0.6, 2, 24);
    // same deploy as B, but audited over a different worker count
    persist_session(&dir_b2, "deploy-b", 0.6, 1, 24);

    let a = SessionInfo::load(&dir_a).expect("session A loads");
    let b = SessionInfo::load(&dir_b).expect("session B loads");
    assert_eq!(a.session_id(), "deploy-a");
    assert_eq!(a.deploy_tag(), "accept");
    assert_eq!(match_sessions(&a, &b, MatchMode::Exact), MatchVerdict::Exact);

    let diff = diff_sessions(&a, &b, &DiffConfig::default()).expect("same workload diffs");
    // the two matmul call sites carry the regression and rank first
    // (identical per-op costs → bit-equal deltas → label tiebreak)
    assert!(diff.labels.len() >= 5, "all serving labels ledgered");
    assert_eq!(diff.labels[0].label, "serve.out");
    assert_eq!(diff.labels[1].label, "serve.proj");
    for l in &diff.labels[..2] {
        assert!(l.delta_j > 0.0, "{}: must regress", l.label);
        assert!(l.delta_frac > 0.10, "{}: visible regression", l.label);
    }
    for l in &diff.labels[2..] {
        assert!(l.delta_j.abs() < 1e-12, "{}: non-matmul labels unchanged", l.label);
    }
    // session B wastes more against its in-session reference too
    assert!(diff.wasted_b_j > diff.wasted_a_j);
    // the regression gate trips at 5 %, stays quiet at 90 %
    assert!(diff.regressed(0.05));
    assert!(!diff.regressed(0.90));
    // aligned same-workload sessions: every window pairs positionally
    assert!(diff.windows.aligned > 0);
    assert_eq!(diff.windows.forced, 0);
    assert_eq!(diff.windows.skipped_a + diff.windows.skipped_b, 0);

    // bit-reproducible: a fresh load + diff renders identically, and a
    // session persisted under a different worker count diffs to the
    // bit-identical report (worker-count independence end-to-end)
    let rendered = render_session_diff(&diff);
    assert!(rendered.contains("REGRESSED"), "{rendered}");
    let again = diff_sessions(
        &SessionInfo::load(&dir_a).unwrap(),
        &SessionInfo::load(&dir_b).unwrap(),
        &DiffConfig::default(),
    )
    .unwrap();
    assert_eq!(render_session_diff(&again), rendered, "diff must be deterministic");
    let b2 = SessionInfo::load(&dir_b2).expect("session B2 loads");
    let diff2 = diff_sessions(&a, &b2, &DiffConfig::default()).unwrap();
    assert_eq!(render_session_diff(&diff2), rendered, "worker count leaked into the diff");
    for (x, y) in diff.labels.iter().zip(diff2.labels.iter()) {
        assert_eq!(x.label, y.label);
        assert_eq!(x.delta_j.to_bits(), y.delta_j.to_bits(), "{}", x.label);
        assert_eq!(x.energy_b_j.to_bits(), y.energy_b_j.to_bits(), "{}", x.label);
    }

    let _ = std::fs::remove_dir_all(&dir_a);
    let _ = std::fs::remove_dir_all(&dir_b);
    let _ = std::fs::remove_dir_all(&dir_b2);
}

/// Sessions that ran different workloads are refused with a reasoned
/// diagnostic in exact mode; tolerant mode accepts them only above the
/// configured label-multiset overlap, and the session index groups
/// matching sessions together.
#[test]
fn mismatched_workloads_are_refused_with_a_diagnostic() {
    let dir_a = tmp_dir("session-ref-a");
    let dir_c = tmp_dir("session-ref-c");
    persist_session(&dir_a, "deploy-a", 1.0, 2, 24);
    // same label set, half the requests: overlap 0.5
    persist_session(&dir_c, "deploy-c", 1.0, 2, 12);

    let a = SessionInfo::load(&dir_a).unwrap();
    let c = SessionInfo::load(&dir_c).unwrap();
    let MatchVerdict::Incomparable { reason } = match_sessions(&a, &c, MatchMode::Exact) else {
        panic!("different op counts must be incomparable in exact mode");
    };
    assert!(reason.contains("do not match"), "{reason}");
    assert!(reason.contains("--tolerant"), "{reason}");
    // diff refuses outright, carrying the diagnostic
    let err = diff_sessions(&a, &c, &DiffConfig::default()).unwrap_err();
    assert!(format!("{err}").contains("not comparable"), "{err}");

    // tolerant mode: overlap is exactly 0.5 (half the ops shared)
    let v = match_sessions(&a, &c, MatchMode::Tolerant { min_overlap: 0.4 });
    let MatchVerdict::Tolerant { overlap } = v else {
        panic!("expected tolerant match, got {v:?}");
    };
    assert!((overlap - 0.5).abs() < 1e-12, "overlap {overlap}");
    assert!(matches!(
        match_sessions(&a, &c, MatchMode::Tolerant { min_overlap: 0.8 }),
        MatchVerdict::Incomparable { .. }
    ));
    // a tolerant diff proceeds and notes the op-count drift
    let cfg = DiffConfig { mode: MatchMode::Tolerant { min_overlap: 0.4 }, ..Default::default() };
    let diff = diff_sessions(&a, &c, &cfg).unwrap();
    assert!(matches!(diff.verdict, MatchVerdict::Tolerant { .. }));
    assert!(diff.notes.iter().any(|n| n.contains("different op counts")), "{:?}", diff.notes);

    // the index groups the matching pair and isolates the odd one out
    let idx = SessionIndex::scan(&[dir_a.clone(), dir_c.clone()]).unwrap();
    assert_eq!(idx.groups(MatchMode::Exact), vec![vec![0], vec![1]]);
    assert_eq!(
        idx.groups(MatchMode::Tolerant { min_overlap: 0.4 }),
        vec![vec![0, 1]],
        "tolerant grouping joins the overlapping sessions"
    );

    let _ = std::fs::remove_dir_all(&dir_a);
    let _ = std::fs::remove_dir_all(&dir_c);
}

/// Indexing a fleet of shard directories must scale with the number of
/// *files*, not the number of persisted snapshot bytes: the lazy scan
/// reads only each file's header line, in bounded chunks. Proven by
/// metering every byte pulled through the injected readers over a
/// 1000-session-directory tree whose files are dominated by
/// non-header payload — and a directory without any session header is
/// still refused, header-only scan or not.
#[test]
fn session_index_scan_reads_o_files_bytes_over_a_thousand_dirs() {
    use magneton::fingerprint::WorkloadSig;
    use magneton::telemetry::{SessionHeader, Snapshot};
    use std::cell::Cell;
    use std::rc::Rc;

    let base = tmp_dir("session-index-scale");
    let mut sig = WorkloadSig::new();
    sig.add("serve.proj", "matmul");
    let header_line = Snapshot::Session {
        header: SessionHeader::new("scale", "tag", "pair", &sig, "steady", 0xfeed),
    }
    .to_line();
    // payload the scan must NOT read: opaque wide lines after line 1
    let pad = format!("{{\"type\":\"pad\",\"fill\":\"{}\"}}", "x".repeat(480));
    let mut dirs: Vec<PathBuf> = Vec::new();
    let mut total_bytes = 0u64;
    for i in 0..1000 {
        let dir = base.join(format!("d{i:04}"));
        std::fs::create_dir_all(&dir).unwrap();
        let mut body = String::with_capacity(9 * 1024);
        body.push_str(&header_line);
        body.push('\n');
        for _ in 0..16 {
            body.push_str(&pad);
            body.push('\n');
        }
        std::fs::write(dir.join("pair-000-shard-000000.ndjson"), &body).unwrap();
        total_bytes += body.len() as u64;
        dirs.push(dir);
    }
    // a producer killed before its first newline leaves a file with a
    // single torn fragment: skipped by the scan, never fatal
    std::fs::write(dirs[0].join("pair-001-torn-000000.ndjson"), "{\"type\":\"sess").unwrap();

    let counted = Rc::new(Cell::new(0u64));
    let meter = Rc::clone(&counted);
    let idx = SessionIndex::scan_with(&dirs, &mut |p: &std::path::Path| {
        std::fs::File::open(p).map(|f| CountingReader::new(f, Rc::clone(&meter)))
    })
    .expect("header-only scan over 1000 session dirs");
    assert_eq!(idx.sessions.len(), 1000);
    for s in &idx.sessions {
        assert_eq!(s.session_id(), "scale");
        assert_eq!(s.headers.len(), 1);
    }
    // O(files) bytes: at most two 512-byte chunks per file (the header
    // line fits in the first), nowhere near the persisted payload
    let files = 1001u64;
    assert!(
        counted.get() <= files * 1024,
        "lazy scan read {} bytes for {files} files — more than the header chunks",
        counted.get()
    );
    assert!(
        counted.get() * 5 <= total_bytes,
        "lazy scan read {} of {total_bytes} payload bytes — it is not lazy",
        counted.get()
    );

    // a directory whose files carry no session header (e.g. only a
    // fleet ranking sink) is refused by the index, same as a full load
    let headerless = base.join("headerless");
    std::fs::create_dir_all(&headerless).unwrap();
    let fleet_line = Snapshot::Fleet { ranking: vec![] }.to_line();
    std::fs::write(headerless.join("fleet-000000.ndjson"), format!("{fleet_line}\n")).unwrap();
    let err = SessionIndex::scan(&[dirs[0].clone(), headerless]).unwrap_err();
    assert!(format!("{err}").contains("no session header"), "{err}");

    let _ = std::fs::remove_dir_all(&base);
}

/// A directory persisted without session headers is rejected with a
/// pointer at the fix, not compared garbage-to-garbage.
#[test]
fn headerless_directories_are_rejected() {
    let dir = tmp_dir("session-headerless");
    let mut fleet = StreamFleet::new(DeviceSpec::h200_sim());
    fleet.cfg.window_ops = 40;
    fleet.cfg.hop_ops = 40;
    fleet.snapshot_dir = Some(dir.clone());
    // no session_id: sinks write data but no headers
    fleet.add_pair("solo", mk_stream_run("a", 7, 1.0, 12), mk_stream_run("b", 7, 1.0, 12));
    let r = fleet.run();
    assert_eq!(r.snapshot_errors, 0);
    let err = SessionInfo::load(&dir).unwrap_err();
    assert!(format!("{err}").contains("no session header"), "{err}");
    let _ = std::fs::remove_dir_all(&dir);
}
