//! End-to-end telemetry acceptance: persisting a streaming fleet audit
//! (`magneton stream --snapshot-dir`) and replaying it (`magneton
//! replay --dir`) must reproduce the cumulative waste ledger and the
//! fleet ranking **bit-for-bit**, and a simultaneous multi-pair
//! divergence must coalesce into exactly one fleet-wide event.

use std::path::PathBuf;

use magneton::coordinator::fleet::{correlate_divergences, StreamFleet, StreamFleetEntry};
use magneton::coordinator::SysRun;
use magneton::dispatch::Env;
use magneton::energy::{DeviceSpec, Segment};
use magneton::exec::KernelRecord;
use magneton::graph::OpKind;
use magneton::stream::{StreamAuditor, StreamConfig};
use magneton::telemetry::Replay;
use magneton::trace::Frame;
use magneton::util::Prng;
use magneton::workload::{serving_dispatcher, serving_stream_program, ServingStream};

fn tmp_dir(tag: &str) -> PathBuf {
    let dir =
        std::env::temp_dir().join(format!("magneton-telemetry-it-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn mk_stream_run(label: &str, seed: u64, eff: f64, requests: usize) -> SysRun {
    let mut rng = Prng::new(seed);
    let spec = ServingStream { requests, batch: 64, d_model: 128 };
    SysRun::new(label, serving_dispatcher(eff), Env::new(), serving_stream_program(&mut rng, &spec))
}

/// The tentpole acceptance path: run a streaming fleet with a snapshot
/// directory, load the directory back, and check the replayed waste
/// ledger and fleet ranking against the live report bit-for-bit.
#[test]
fn snapshots_reproduce_ledger_and_ranking_bit_for_bit() {
    let dir = tmp_dir("fleet");
    let mut fleet = StreamFleet::new(DeviceSpec::h200_sim());
    fleet.cfg.window_ops = 40;
    fleet.cfg.hop_ops = 40;
    fleet.cfg.ring_cap = 64;
    fleet.snapshot_dir = Some(dir.clone());
    for (i, eff) in [0.6, 1.0, 0.7].iter().enumerate() {
        fleet.add_pair(
            &format!("stream-{i}"),
            mk_stream_run("sys-a", 90 + i as u64, *eff, 24),
            mk_stream_run("sys-b", 90 + i as u64, 1.0, 24),
        );
    }
    let live = fleet.run();
    assert_eq!(live.snapshot_errors, 0, "snapshot writes must succeed");
    assert!(live.total_wasted_j > 0.0, "the harness needs real waste to compare");

    let replay = Replay::load(&dir).expect("snapshot dir loads");
    assert_eq!(replay.summaries.len(), 3, "one summary per pair");
    assert_eq!(replay.rankings.len(), 1, "one persisted fleet ranking");
    assert!(replay.resyncs.is_empty(), "same-workload pairs never resync");

    // per-pair cumulative waste ledger: bit-identical floats, identical
    // label attribution
    for e in &live.entries {
        let s = replay.summary_of(&e.name).expect("pair summary persisted");
        assert_eq!(s.wasted_j.to_bits(), e.summary.wasted_j.to_bits(), "{}", e.name);
        assert_eq!(s.energy_a_j.to_bits(), e.summary.energy_a_j.to_bits(), "{}", e.name);
        assert_eq!(s.energy_b_j.to_bits(), e.summary.energy_b_j.to_bits(), "{}", e.name);
        assert_eq!(s.ops, e.summary.ops, "{}", e.name);
        assert_eq!(s.windows, e.summary.windows, "{}", e.name);
        assert_eq!(s.fingerprint_a, e.summary.fingerprint_a, "{}", e.name);
        assert_eq!(s.top_labels.len(), e.summary.top_labels.len(), "{}", e.name);
        for (x, y) in s.top_labels.iter().zip(e.summary.top_labels.iter()) {
            assert_eq!(x.0, y.0);
            assert_eq!(x.1.to_bits(), y.1.to_bits(), "label {} ledger drifted", x.0);
            assert_eq!(x.2, y.2);
        }
    }

    // the persisted fleet ranking reproduces the live ranking: same
    // order, bit-identical waste
    let ranking = &replay.rankings[0];
    assert_eq!(ranking.len(), live.entries.len());
    for (r, e) in ranking.iter().zip(live.entries.iter()) {
        assert_eq!(r.name, e.name, "ranking order drifted");
        assert_eq!(r.wasted_j.to_bits(), e.summary.wasted_j.to_bits());
        assert_eq!(r.windows_flagged, e.summary.windows_flagged);
    }
    assert_eq!(replay.verify_ranking(), Ok(3));

    // every emitted window was persisted (nothing rotated away at this
    // size), so offline re-rendering sees the full rolling history
    let live_windows: usize = live.entries.iter().map(|e| e.summary.windows).sum();
    assert_eq!(replay.windows.len(), live_windows);

    let _ = std::fs::remove_dir_all(&dir);
}

fn rec(label: &str, op: OpKind, energy_j: f64, time_us: f64) -> KernelRecord {
    KernelRecord {
        node: 0,
        op,
        label: label.to_string(),
        api: "api".into(),
        dispatch_key: op.name().to_string(),
        kernel: format!("k_{label}"),
        time_us,
        energy_j,
        avg_power_w: energy_j / (time_us * 1e-6),
        corr_id: 0,
        bb_trace: vec![],
        call_path: vec![Frame::py("serve")],
        moments: vec![],
    }
}

fn seg_after(t0: f64, dur: f64, watts: f64) -> Segment {
    Segment { t_start_us: t0, t_end_us: t0 + dur, watts }
}

/// Serving-shaped op cycle (period 5) with per-kind energies distinct
/// enough that any mispairing would flag.
fn cycle_op(i: usize) -> (&'static str, OpKind, f64) {
    match i % 5 {
        0 => ("serve.proj", OpKind::MatMul, 0.30),
        1 => ("serve.scale", OpKind::Mul, 0.02),
        2 => ("serve.act", OpKind::Gelu, 0.05),
        3 => ("serve.out", OpKind::MatMul, 0.30),
        _ => ("serve.softmax", OpKind::Softmax, 0.08),
    }
}

/// Run one 1000-op stream pair through a real auditor, dropping side
/// A's event at `skip_at` (if any), and wrap the summary as a fleet
/// entry.
fn audited_entry(name: &str, skip_at: Option<usize>) -> StreamFleetEntry {
    let cfg = StreamConfig {
        window_ops: 100,
        hop_ops: 100,
        ring_cap: 128,
        nvml: None,
        ..Default::default()
    };
    let mut aud = StreamAuditor::new(cfg, 90.0);
    let (mut ta, mut tb) = (0.0, 0.0);
    for i in 0..1000 {
        let (label, op, e) = cycle_op(i);
        if Some(i) != skip_at {
            aud.ingest_a(&rec(label, op, e, 100.0), seg_after(ta, 100.0, e / 100e-6));
            ta += 100.0;
        }
        aud.ingest_b(&rec(label, op, e, 100.0), seg_after(tb, 100.0, e / 100e-6));
        tb += 100.0;
    }
    let summary = aud.finish();
    let expected = usize::from(skip_at.is_some());
    assert_eq!(summary.resyncs, expected, "{name}: unexpected resync count");
    StreamFleetEntry { name: name.to_string(), summary, snapshot_errors: 0 }
}

/// The acceptance scenario: three pairs drop a kernel at (nearly) the
/// same op position — a shared-cause divergence. The fleet correlation
/// must emit exactly one `FleetDivergence` with all three pairs
/// attributed, instead of three per-pair alarms.
#[test]
fn simultaneous_three_pair_divergence_yields_one_fleet_event() {
    let entries = vec![
        audited_entry("serving-0", Some(437)),
        audited_entry("serving-1", Some(438)),
        audited_entry("serving-2", Some(439)),
    ];
    let divs = correlate_divergences(&entries, 100, 2);
    assert_eq!(divs.len(), 1, "exactly one fleet-wide divergence event");
    let d = &divs[0];
    assert_eq!(d.pairs.len(), 3, "all three pairs attributed");
    assert!(d.at_ops_min >= 436 && d.at_ops_max <= 440, "{}..{}", d.at_ops_min, d.at_ops_max);
    for p in &d.pairs {
        assert_eq!(p.resyncs, 1, "{}", p.name);
        assert_eq!(p.skipped, 1, "{}: one dropped kernel costs one skip", p.name);
    }

    // one pair diverging alone stays below the correlation threshold
    let solo = vec![audited_entry("serving-0", Some(437)), audited_entry("serving-1", None)];
    assert!(correlate_divergences(&solo, 100, 2).is_empty());
}
