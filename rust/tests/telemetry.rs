//! End-to-end telemetry acceptance: persisting a streaming fleet audit
//! (`magneton stream --snapshot-dir`) and replaying it (`magneton
//! replay --dir`) must reproduce the cumulative waste ledger and the
//! fleet ranking **bit-for-bit**, a simultaneous multi-pair divergence
//! must coalesce into exactly one fleet-wide event, and session headers
//! must identify the persisted workload even after rotation.

mod common;

use common::{audited_cycle_entry, mk_stream_run, tmp_dir};
use magneton::coordinator::fleet::{correlate_divergences, StreamFleet};
use magneton::energy::DeviceSpec;
use magneton::stream::workload_sig_of_program;
use magneton::telemetry::Replay;

/// The tentpole acceptance path: run a streaming fleet with a snapshot
/// directory, load the directory back, and check the replayed waste
/// ledger and fleet ranking against the live report bit-for-bit.
#[test]
fn snapshots_reproduce_ledger_and_ranking_bit_for_bit() {
    let dir = tmp_dir("telemetry-fleet");
    let mut fleet = StreamFleet::new(DeviceSpec::h200_sim());
    fleet.cfg.window_ops = 40;
    fleet.cfg.hop_ops = 40;
    fleet.cfg.ring_cap = 64;
    fleet.snapshot_dir = Some(dir.clone());
    fleet.session_id = Some("telemetry-acceptance".into());
    fleet.deploy_tag = "pr5".into();
    for (i, eff) in [0.6, 1.0, 0.7].iter().enumerate() {
        fleet.add_pair(
            &format!("stream-{i}"),
            mk_stream_run("sys-a", 90 + i as u64, *eff, 24),
            mk_stream_run("sys-b", 90 + i as u64, 1.0, 24),
        );
    }
    let live = fleet.run();
    assert_eq!(live.snapshot_errors, 0, "snapshot writes must succeed");
    assert!(live.total_wasted_j > 0.0, "the harness needs real waste to compare");

    let replay = Replay::load(&dir).expect("snapshot dir loads");
    assert_eq!(replay.summaries.len(), 3, "one summary per pair");
    assert_eq!(replay.rankings.len(), 1, "one persisted fleet ranking");
    assert!(replay.resyncs.is_empty(), "same-workload pairs never resync");

    // session headers: one per pair scope, all carrying the session
    // identity and the static workload fingerprint of the pair program
    assert_eq!(replay.sessions.len(), 3, "one header per pair sink");
    let expected_fp = {
        let probe = mk_stream_run("sys-a", 90, 1.0, 24);
        workload_sig_of_program(&probe.prog).fp()
    };
    for h in &replay.sessions {
        assert_eq!(h.session_id, "telemetry-acceptance");
        assert_eq!(h.deploy_tag, "pr5");
        assert_eq!(h.workload_fp, expected_fp, "{}", h.scope);
        assert_eq!(h.total_ops, 24 * 5, "{}", h.scope);
        assert_eq!(h.arrival, "steady");
    }

    // per-pair ledgers persisted at finish, one per pair
    assert_eq!(replay.ledgers.len(), 3);

    // per-pair cumulative waste ledger: bit-identical floats, identical
    // label attribution
    for e in &live.entries {
        let s = replay.summary_of(&e.name).expect("pair summary persisted");
        assert_eq!(s.wasted_j.to_bits(), e.summary.wasted_j.to_bits(), "{}", e.name);
        assert_eq!(s.energy_a_j.to_bits(), e.summary.energy_a_j.to_bits(), "{}", e.name);
        assert_eq!(s.energy_b_j.to_bits(), e.summary.energy_b_j.to_bits(), "{}", e.name);
        assert_eq!(s.ops, e.summary.ops, "{}", e.name);
        assert_eq!(s.windows, e.summary.windows, "{}", e.name);
        assert_eq!(s.fingerprint_a, e.summary.fingerprint_a, "{}", e.name);
        assert_eq!(s.top_labels.len(), e.summary.top_labels.len(), "{}", e.name);
        for (x, y) in s.top_labels.iter().zip(e.summary.top_labels.iter()) {
            assert_eq!(x.0, y.0);
            assert_eq!(x.1.to_bits(), y.1.to_bits(), "label {} ledger drifted", x.0);
            assert_eq!(x.2, y.2);
        }
        // the persisted label ledger covers every matched pair
        let ledger = replay.ledger_of(&e.name).expect("pair ledger persisted");
        assert_eq!(ledger.iter().map(|l| l.ops).sum::<usize>(), e.summary.ops, "{}", e.name);
        let led_e_a: f64 = ledger.iter().map(|l| l.energy_a_j).sum();
        assert!(
            (led_e_a - e.summary.energy_a_j).abs() < 1e-9 * e.summary.energy_a_j.max(1.0),
            "{}: ledger energy drifted",
            e.name
        );
    }

    // the persisted fleet ranking reproduces the live ranking: same
    // order, bit-identical waste
    let ranking = &replay.rankings[0];
    assert_eq!(ranking.len(), live.entries.len());
    for (r, e) in ranking.iter().zip(live.entries.iter()) {
        assert_eq!(r.name, e.name, "ranking order drifted");
        assert_eq!(r.wasted_j.to_bits(), e.summary.wasted_j.to_bits());
        assert_eq!(r.windows_flagged, e.summary.windows_flagged);
    }
    assert_eq!(replay.verify_ranking(), Ok(3));

    // every emitted window was persisted (nothing rotated away at this
    // size), so offline re-rendering sees the full rolling history
    let live_windows: usize = live.entries.iter().map(|e| e.summary.windows).sum();
    assert_eq!(replay.windows.len(), live_windows);

    let _ = std::fs::remove_dir_all(&dir);
}

/// The acceptance scenario: three pairs drop a kernel at (nearly) the
/// same op position — a shared-cause divergence. The fleet correlation
/// must emit exactly one `FleetDivergence` with all three pairs
/// attributed, instead of three per-pair alarms.
#[test]
fn simultaneous_three_pair_divergence_yields_one_fleet_event() {
    let entries = vec![
        audited_cycle_entry("serving-0", Some(437)),
        audited_cycle_entry("serving-1", Some(438)),
        audited_cycle_entry("serving-2", Some(439)),
    ];
    let divs = correlate_divergences(&entries, 100, 2);
    assert_eq!(divs.len(), 1, "exactly one fleet-wide divergence event");
    let d = &divs[0];
    assert_eq!(d.pairs.len(), 3, "all three pairs attributed");
    assert!(d.at_ops_min >= 436 && d.at_ops_max <= 440, "{}..{}", d.at_ops_min, d.at_ops_max);
    for p in &d.pairs {
        assert_eq!(p.resyncs, 1, "{}", p.name);
        assert_eq!(p.skipped, 1, "{}: one dropped kernel costs one skip", p.name);
    }

    // one pair diverging alone stays below the correlation threshold
    let solo = vec![audited_cycle_entry("serving-0", Some(437)), audited_cycle_entry("serving-1", None)];
    assert!(correlate_divergences(&solo, 100, 2).is_empty());
}
