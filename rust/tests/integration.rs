//! Integration tests across the three layers.
//!
//! These tests require `make artifacts` (they exercise the real
//! jax→HLO→PJRT path); without artifacts they skip with a note so that
//! `cargo test` stays green on a fresh checkout.

mod common;

use common::mag;
use magneton::dispatch::{Env, KernelChoice, Routine};
use magneton::energy::{ComputeUnit, DeviceSpec};
use magneton::exec::{Dispatcher, Program};
use magneton::graph::{Attrs, Graph, OpKind};
use magneton::runtime::{default_artifact_dir, PjrtMomentEngine, PjrtRuntime};
use magneton::tensor::Tensor;
use magneton::util::Prng;

/// Mirror of python/compile/model.py TEST_* constants.
const B: usize = 2;
const S: usize = 8;
const D: usize = 32;
const H: usize = 4;
const F: usize = 64;

fn artifacts_available() -> bool {
    default_artifact_dir().join("gpt2_block_b.hlo.txt").exists()
}

/// Parameter tensors in python block_param_shapes() order.
fn make_params(rng: &mut Prng) -> Vec<Tensor> {
    let scale = 1.0 / (D as f32).sqrt();
    let shapes: Vec<Vec<usize>> = vec![
        vec![D], vec![D],
        vec![D, 3 * D], vec![3 * D],
        vec![D, D], vec![D],
        vec![D], vec![D],
        vec![D, F], vec![F],
        vec![F, D], vec![D],
    ];
    let mut params: Vec<Tensor> = shapes
        .iter()
        .map(|s| {
            let n: usize = s.iter().product();
            let data: Vec<f32> = (0..n).map(|_| rng.normal_f32() * scale).collect();
            Tensor::from_vec(data, s)
        })
        .collect();
    // LN gains near 1 (match test_model.py's construction spirit)
    for idx in [0usize, 6] {
        let v: Vec<f32> = params[idx].to_vec().iter().map(|x| 1.0 + 0.1 * x.abs()).collect();
        params[idx] = Tensor::from_vec(v, params[idx].shape());
    }
    params
}

/// Rust-executor graph mirroring model.py's fused (variant B) block.
fn rust_block_program(x: &Tensor, params: &[Tensor]) -> Program {
    let mut g = Graph::new("rust-block");
    let xi = g.add(OpKind::Input, &[], "x");
    let w: Vec<usize> = (0..12).map(|i| g.add(OpKind::Weight, &[], &format!("p{i}"))).collect();
    let (ln1_g, ln1_b, qkv_w, qkv_b, out_w, out_b, ln2_g, ln2_b, ff1_w, ff1_b, ff2_w, ff2_b) =
        (w[0], w[1], w[2], w[3], w[4], w[5], w[6], w[7], w[8], w[9], w[10], w[11]);

    let mut at = Attrs::new();
    at.insert("input_contiguous".into(), "true".into());
    let ln1 = g.add_attrs(OpKind::LayerNorm, &[xi, ln1_g, ln1_b], "ln1", at.clone());
    let qkv_m = g.add(OpKind::MatMul, &[ln1, qkv_w], "qkv.matmul");
    let qkv = g.add(OpKind::Add, &[qkv_m, qkv_b], "qkv.bias");
    let mut split = |g: &mut Graph, i: usize, n: &str| {
        let mut a = Attrs::new();
        a.insert("dim".into(), "1".into());
        a.insert("chunks".into(), "3".into());
        a.insert("index".into(), i.to_string());
        g.add_attrs(OpKind::SplitChunk, &[qkv], n, a)
    };
    let q2 = split(&mut g, 0, "q");
    let k2 = split(&mut g, 1, "k");
    let v2 = split(&mut g, 2, "v");
    let dh = D / H;
    let mut r4 = |g: &mut Graph, t: usize, n: &str| {
        let mut a = Attrs::new();
        a.insert("shape".into(), format!("{B},{S},{H},{dh}"));
        g.add_attrs(OpKind::Reshape, &[t], n, a)
    };
    let q4 = r4(&mut g, q2, "q4");
    let k4 = r4(&mut g, k2, "k4");
    let v4 = r4(&mut g, v2, "v4");
    let mut a = Attrs::new();
    a.insert("layout".into(), "nhd".into());
    let attn = g.add_attrs(OpKind::Attention, &[q4, k4, v4], "attn", a);
    let mut a = Attrs::new();
    a.insert("shape".into(), format!("{},{}", B * S, D));
    let attn2 = g.add_attrs(OpKind::Reshape, &[attn], "attn2d", a);
    let proj_m = g.add(OpKind::MatMul, &[attn2, out_w], "proj.matmul");
    let proj = g.add(OpKind::Add, &[proj_m, out_b], "proj.bias");
    let res1 = g.add(OpKind::Add, &[xi, proj], "res1");
    let ln2 = g.add_attrs(OpKind::LayerNorm, &[res1, ln2_g, ln2_b], "ln2", at);
    let h1m = g.add(OpKind::MatMul, &[ln2, ff1_w], "ff1.matmul");
    let h1 = g.add(OpKind::Add, &[h1m, ff1_b], "ff1.bias");
    let act = g.add_attr1(OpKind::Gelu, &[h1], "gelu", "approx", "tanh");
    let h2m = g.add(OpKind::MatMul, &[act, ff2_w], "ff2.matmul");
    let h2 = g.add(OpKind::Add, &[h2m, ff2_b], "ff2.bias");
    let out = g.add(OpKind::Add, &[res1, h2], "res2");
    g.add(OpKind::Output, &[out], "out");

    let mut p = Program::new(g);
    p.feed(0, x.clone());
    for (i, t) in params.iter().enumerate() {
        p.feed(i + 1, t.clone());
    }
    p
}

/// Exact-f32 dispatcher (CUDA-core matmuls, no TF32 rounding) so the
/// Rust executor numerics can be compared to XLA at tight tolerance.
fn exact_dispatcher() -> Dispatcher {
    let mut d = Dispatcher::new();
    d.register(
        "matmul",
        Routine::direct("exact.matmul", vec![], KernelChoice::new("fp32_gemm", ComputeUnit::CudaCore)),
    );
    d
}

#[test]
fn pjrt_block_variants_agree_with_each_other() {
    if !artifacts_available() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let mut rt = PjrtRuntime::cpu().unwrap();
    rt.load_dir(&default_artifact_dir()).unwrap();
    let mut rng = Prng::new(77);
    let x = Tensor::randn(&mut rng, &[B * S, D]);
    let params = make_params(&mut rng);
    let mut inputs: Vec<(Vec<f32>, Vec<usize>)> = vec![(x.to_vec(), x.shape().to_vec())];
    for p in &params {
        inputs.push((p.to_vec(), p.shape().to_vec()));
    }
    let refs: Vec<(&[f32], &[usize])> =
        inputs.iter().map(|(d, s)| (d.as_slice(), s.as_slice())).collect();
    let a = rt.execute_f32("gpt2_block_a", &refs).unwrap();
    let b = rt.execute_f32("gpt2_block_b", &refs).unwrap();
    assert_eq!(a[0].len(), B * S * D);
    let max_abs = a[0].iter().fold(0.0f32, |m, x| m.max(x.abs()));
    let max_diff = a[0]
        .iter()
        .zip(b[0].iter())
        .fold(0.0f32, |m, (x, y)| m.max((x - y).abs()));
    assert!(max_diff / max_abs < 1e-4, "variant divergence {}", max_diff / max_abs);
}

#[test]
fn rust_executor_matches_xla_reference() {
    if !artifacts_available() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let mut rt = PjrtRuntime::cpu().unwrap();
    rt.load_dir(&default_artifact_dir()).unwrap();
    let mut rng = Prng::new(78);
    let x = Tensor::randn(&mut rng, &[B * S, D]);
    let params = make_params(&mut rng);

    // XLA reference output
    let mut inputs: Vec<(Vec<f32>, Vec<usize>)> = vec![(x.to_vec(), x.shape().to_vec())];
    for p in &params {
        inputs.push((p.to_vec(), p.shape().to_vec()));
    }
    let refs: Vec<(&[f32], &[usize])> =
        inputs.iter().map(|(d, s)| (d.as_slice(), s.as_slice())).collect();
    let xla_out = rt.execute_f32("gpt2_block_b", &refs).unwrap();

    // Rust executor output on the equivalent graph
    let prog = rust_block_program(&x, &params);
    let exec = magneton::exec::Executor::new(DeviceSpec::h200_sim(), exact_dispatcher(), Env::new());
    let arts = exec.run(&prog);
    let rust_out = arts.output().to_vec();

    assert_eq!(rust_out.len(), xla_out[0].len());
    let max_abs = xla_out[0].iter().fold(0.0f32, |m, v| m.max(v.abs()));
    let max_diff = rust_out
        .iter()
        .zip(xla_out[0].iter())
        .fold(0.0f32, |m, (a, b)| m.max((a - b).abs()));
    assert!(
        max_diff / max_abs < 2e-3,
        "rust executor diverges from XLA: {}",
        max_diff / max_abs
    );
}

#[test]
fn full_pipeline_with_pjrt_fingerprint_engine() {
    if !artifacts_available() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let engine = PjrtMomentEngine::load(&default_artifact_dir()).unwrap();
    let mut mag = mag();
    mag.engine = Box::new(engine);

    // audit a known case end-to-end with the Pallas-backed engine
    let mut rng = Prng::new(79);
    let scenario = magneton::cases::by_id("c8").unwrap();
    let (a, b) = (scenario.build)(&mut rng);
    let out = mag.audit(&a, &b);
    assert!(out.detected(), "c8 not detected with PJRT engine");
    assert!(out
        .diagnoses
        .iter()
        .any(|(_, d)| d.render().contains("allow_tf32")), "c8 diagnosis missing allow_tf32");
}

#[test]
fn known_cases_detection_summary() {
    // The Table 2 headline: 15/16 known cases diagnosed, c11 missed by
    // design. (Rust engine for speed; the PJRT engine is exercised above.)
    let mag = mag();
    let mut rng = Prng::new(2026);
    let mut diagnosed = 0;
    let mut missed: Vec<&str> = Vec::new();
    for s in magneton::cases::known_cases() {
        let (a, b) = (s.build)(&mut rng);
        let out = mag.audit(&a, &b);
        let ok = out.detected()
            && out
                .diagnoses
                .iter()
                .any(|(f, d)| {
                    s.expect.is_empty()
                        || d.render().to_lowercase().contains(&s.expect.to_lowercase())
                        || f.labels.iter().any(|l| l.to_lowercase().contains(&s.expect.to_lowercase()))
                });
        if s.expect_undetected {
            assert!(!out.detected(), "{} should be undetectable (CPU-side)", s.id);
        } else if ok {
            diagnosed += 1;
        } else {
            missed.push(s.id);
        }
    }
    assert!(
        diagnosed >= 15,
        "only {diagnosed}/15 detectable cases diagnosed; missed: {missed:?}"
    );
}

#[test]
fn new_issues_detection_summary() {
    let mag = mag();
    let mut rng = Prng::new(2027);
    let mut found = 0;
    let mut missed: Vec<&str> = Vec::new();
    for s in magneton::cases::new_cases() {
        let (a, b) = (s.build)(&mut rng);
        let out = mag.audit(&a, &b);
        if out.detected() {
            found += 1;
        } else {
            missed.push(s.id);
        }
    }
    assert!(found >= 7, "only {found}/8 new issues exposed; missed: {missed:?}");
}
