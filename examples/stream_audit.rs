//! Streaming online-audit demo: a ≥5000-operator serving stream audited
//! chunk-by-chunk against an energy-optimal reference under a Poisson
//! request-arrival process (idle lulls materialised in the power
//! rings), with retained power-trace memory bounded by the ring
//! capacity — never the stream length. Finishes with a small streaming
//! *fleet* audit over three concurrent serving pairs.
//!
//! ```sh
//! cargo run --release --example stream_audit [-- --requests 1200 --window 250 --ring 512 --rate 300]
//! ```

use magneton::coordinator::fleet::{drive_pair_with_arrivals, StreamFleet};
use magneton::coordinator::SysRun;
use magneton::dispatch::Env;
use magneton::energy::DeviceSpec;
use magneton::exec::Executor;
use magneton::report;
use magneton::stream::{StreamAuditor, StreamConfig};
use magneton::util::cli::Args;
use magneton::util::Prng;
use magneton::workload::{serving_dispatcher, serving_stream_program, ArrivalProcess, ServingStream};

fn main() {
    let args = Args::from_env();
    // ≥1000 requests keeps the demo stream at ≥5000 operators
    let requests: usize = args.get_parse("requests", 1200usize).max(1000);
    let spec = ServingStream { requests, ..Default::default() };
    let window_ops = args.get_parse("window", 250usize);
    let cfg = StreamConfig {
        window_ops,
        hop_ops: window_ops,
        ring_cap: args.get_parse("ring", 512usize),
        // bounded report buffer: we drain every window, so nothing may drop
        max_emitted: 64,
        ..StreamConfig::default()
    };
    let arrival = ArrivalProcess::Poisson { rate_hz: args.get_parse("rate", 300.0f64) };
    let device = DeviceSpec::h200_sim();
    let seed: u64 = args.get_parse("seed", 2026u64);

    println!(
        "auditing a {}-operator serving stream (window {} pairs, ring {} segments, {arrival:?} arrivals)...\n",
        spec.kernel_ops(),
        cfg.window_ops,
        cfg.ring_cap
    );

    // Two sides of the same serving workload: side A's matmul kernel
    // burns extra power at equal speed (quality 0.62), side B is optimal.
    let mut rng_a = Prng::new(seed);
    let mut rng_b = Prng::new(seed);
    let prog_a = serving_stream_program(&mut rng_a, &spec);
    let prog_b = serving_stream_program(&mut rng_b, &spec);
    let mut exec_a = Executor::new(device.clone(), serving_dispatcher(0.62), Env::new());
    let mut exec_b = Executor::new(device.clone(), serving_dispatcher(1.0), Env::new());
    // content guards: per-op moment sketches ride the kernel records
    exec_a.opts.content_sketch = true;
    exec_b.opts.content_sketch = true;

    let mut aud = StreamAuditor::new(cfg.clone(), device.idle_w);
    let mut sa = exec_a.stream(&prog_a);
    let mut sb = exec_b.stream(&prog_b);
    // rolling output: print each detection window as it closes; the
    // shared arrival rng injects the same idle lulls into both rings
    let mut arrival_rng = Prng::new(seed ^ 0xa441_b815);
    let summary = drive_pair_with_arrivals(
        &mut aud,
        &mut sa,
        &mut sb,
        arrival,
        spec.ops_per_request(),
        &mut arrival_rng,
        |w| println!("{}", report::render_window(&w)),
    );
    if let Some(w) = aud.nvml_reading_a() {
        println!("live NVML counter, side A: {w:.0} W");
    }
    println!();
    print!("{}", report::render_stream("inefficient-vs-optimal", &summary));

    // The acceptance invariant: peak retained power-trace memory is set
    // by the ring capacity, not by how long the stream ran — arrival
    // lulls included.
    assert_eq!(summary.ops, spec.kernel_ops());
    assert!(
        summary.peak_retained_segments <= cfg.ring_cap,
        "ring overflowed: {} > {}",
        summary.peak_retained_segments,
        cfg.ring_cap
    );
    // identical workloads under a shared arrival sequence: no
    // divergence, no content alarms, and a drained report buffer
    assert!(summary.aligned, "same-workload pair must stay aligned");
    assert_eq!(summary.resyncs, 0);
    assert_eq!(summary.content_mismatches, 0, "content guard false alarm");
    assert_eq!(summary.reports_dropped, 0, "drained auditor must not drop reports");
    println!(
        "\npeak retained power segments: {} (ring cap {}, stream emitted {} segments/side)",
        summary.peak_retained_segments,
        cfg.ring_cap,
        summary.ops
    );

    // A small streaming fleet over three concurrent serving pairs under
    // the same arrival process.
    println!();
    let mut fleet = StreamFleet::new(device);
    fleet.cfg = cfg;
    fleet.arrival = arrival;
    fleet.ops_per_request = spec.ops_per_request();
    fleet.arrival_seed = seed;
    let fleet_spec = ServingStream { requests: requests / 6, ..spec };
    for (i, eff) in [0.62, 1.0, 0.8].iter().enumerate() {
        let mut ra = Prng::new(seed + 1 + i as u64);
        let mut rb = Prng::new(seed + 1 + i as u64);
        fleet.add_pair(
            &format!("serving-{i}"),
            SysRun::new("sys-a", serving_dispatcher(*eff), Env::new(), serving_stream_program(&mut ra, &fleet_spec)),
            SysRun::new("sys-b", serving_dispatcher(1.0), Env::new(), serving_stream_program(&mut rb, &fleet_spec)),
        );
    }
    println!(
        "streaming fleet: {} pairs x {} ops over {} workers...",
        fleet.len(),
        fleet_spec.kernel_ops(),
        fleet.workers
    );
    let r = fleet.run();
    print!("{}", report::render_stream_fleet(&r));
    for e in &r.entries {
        assert!(e.summary.aligned, "{} diverged", e.name);
        assert!(
            e.summary.peak_retained_segments <= fleet.cfg.ring_cap,
            "{}: ring overflow {}",
            e.name,
            e.summary.peak_retained_segments
        );
    }
}
