//! End-to-end driver (DESIGN.md "End-to-end validation"): the full
//! Magneton pipeline on the complete evaluation suite — all 16 known
//! cases and all 8 new issues — using the Pallas-lowered PJRT
//! fingerprint engine on the hot path when artifacts are available,
//! plus the cross-system fleet comparison. Prints the Table 2 / Table 3
//! replicas with diagnosis verdicts and records the headline metric.
//!
//! ```sh
//! make artifacts && cargo run --release --example e2e_audit
//! ```

use magneton::cases;
use magneton::coordinator::Magneton;
use magneton::energy::DeviceSpec;
use magneton::runtime::{default_artifact_dir, PjrtMomentEngine};
use magneton::util::table::Table;
use magneton::util::Prng;

fn main() {
    let mut mag = Magneton::new(DeviceSpec::h200_sim());
    match PjrtMomentEngine::load(&default_artifact_dir()) {
        Ok(engine) => {
            println!("fingerprint engine: pjrt-pallas (AOT artifacts loaded)\n");
            mag.engine = Box::new(engine);
        }
        Err(e) => {
            println!("fingerprint engine: rust fallback ({e})\n");
        }
    }

    let mut rng = Prng::new(2026);
    let mut t = Table::new(vec!["case", "kind", "detected", "diagnosed", "diff", "category"]);
    let (mut diagnosed, mut detectable) = (0, 0);
    let all: Vec<(cases::Scenario, &str)> = cases::known_cases()
        .into_iter()
        .map(|s| (s, "known"))
        .chain(cases::new_cases().into_iter().map(|s| (s, "new")))
        .collect();
    for (s, kind) in all {
        let (a, b) = (s.build)(&mut rng);
        let out = mag.audit(&a, &b);
        let diag_ok = out.detected()
            && out.diagnoses.iter().any(|(f, d)| {
                s.expect.is_empty()
                    || d.render().to_lowercase().contains(&s.expect.to_lowercase())
                    || f.labels.iter().any(|l| l.to_lowercase().contains(&s.expect.to_lowercase()))
            });
        if !s.expect_undetected {
            detectable += 1;
            if diag_ok {
                diagnosed += 1;
            }
        }
        t.row(vec![
            s.id.to_string(),
            kind.to_string(),
            if out.detected() { "yes" } else { "no" }.to_string(),
            if s.expect_undetected {
                "n/a (CPU-side)".into()
            } else if diag_ok {
                "yes".to_string()
            } else {
                "NO".into()
            },
            format!("{:.1}%", out.e2e_diff_frac * 100.0),
            out.diagnoses
                .first()
                .map(|(_, d)| d.category.name().to_string())
                .unwrap_or_else(|| "-".into()),
        ]);
    }
    println!("{}", t.render());
    println!(
        "HEADLINE: {diagnosed}/{detectable} detectable cases diagnosed \
         (paper: 15/15 known + c11 undetectable by design; 8 new issues, 7 confirmed)"
    );
    let _ = std::fs::create_dir_all("results");
    let _ = std::fs::write(
        "results/e2e_audit.txt",
        format!("{}\nHEADLINE: {diagnosed}/{detectable}\n", t.render()),
    );
    assert!(diagnosed >= detectable - 1, "end-to-end regression");
}
