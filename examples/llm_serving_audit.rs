//! LLM-serving audit: the paper's flagship scenario. Serve the same
//! GPT-2-style model through mini-HF-Transformers and mini-vLLM, then
//! let Magneton explain where HF burns extra energy (unfused GELU,
//! addmm epilogue kernels, HND layout copies, full-sequence LM head).
//!
//! ```sh
//! cargo run --release --example llm_serving_audit
//! ```

use magneton::coordinator::{Magneton, SysRun};
use magneton::energy::DeviceSpec;
use magneton::report::{label_breakdown, render_audit};
use magneton::systems::llm;
use magneton::systems::SystemId;
use magneton::util::Prng;

fn main() {
    let mut rng = Prng::new(2026);
    let params = llm::TransformerParams::new(&mut rng, llm::LlmSpec::gpt2_sim());

    let hf = SysRun::new(
        "mini-hf-transformers",
        llm::hf_dispatcher(),
        llm::default_env(SystemId::MiniHf),
        llm::build_llm(&params, &llm::LlmBuildOpts::hf()),
    );
    let vllm = SysRun::new(
        "mini-vllm",
        llm::vllm_dispatcher(),
        llm::default_env(SystemId::MiniVllm),
        llm::build_llm(&params, &llm::LlmBuildOpts::vllm()),
    );

    let mag = Magneton::new(DeviceSpec::h200_sim());
    let out = mag.audit(&hf, &vllm);
    println!("{}", render_audit("mini-hf-transformers", "mini-vllm", &out));

    println!("\nTop call sites by energy (mini-hf):");
    println!("{}", label_breakdown(&out.a, 8).render());
    println!("Top call sites by energy (mini-vllm):");
    println!("{}", label_breakdown(&out.b, 8).render());

    let tokens = (params.spec.batch * params.spec.seq) as f64;
    println!(
        "J/token: hf {:.3e}  vllm {:.3e}  (ratio {:.2}x)",
        out.a.total_energy_j / tokens,
        out.b.total_energy_j / tokens,
        out.a.total_energy_j / out.b.total_energy_j
    );
}
