//! Operator fuzzing for new-issue discovery (paper §6.3): sweep random
//! convolution workloads across the mini-PyTorch/TF/JAX frameworks and
//! layouts, and report every configuration where some framework wastes
//! energy against a peer computing the same values — this is how the
//! layout-dependent conv trade-off (pytorch-157334 / tf-96396) was
//! found.
//!
//! ```sh
//! cargo run --release --example conv_layout_hunt
//! ```

use magneton::coordinator::{Magneton, SysRun};
use magneton::dispatch::Env;
use magneton::energy::DeviceSpec;
use magneton::systems::frameworks as fw;
use magneton::util::table::Table;
use magneton::util::Prng;

fn main() {
    let mag = Magneton::new(DeviceSpec::h200_sim());
    let mut rng = Prng::new(99);
    let mut t = Table::new(vec!["workload", "wasteful", "efficient", "diff", "diagnosis"]);
    let mut discoveries = 0;

    for trial in 0..12 {
        // fuzz a conv workload
        let spec = fw::ConvSpec {
            batch: *rng.choose(&[2, 4, 8]),
            channels: *rng.choose(&[16, 32, 64]),
            hw: *rng.choose(&[8, 16]),
            out_channels: *rng.choose(&[16, 32]),
            kernel: 3,
            groups: *rng.choose(&[1, 4]),
        };
        if spec.channels % spec.groups != 0 || spec.out_channels % spec.groups != 0 {
            continue;
        }
        let (x, w) = fw::conv_params(&mut rng, spec);
        let candidates = vec![
            ("torch-nchw", fw::build_conv("torch", spec, fw::ConvLayout::Nchw, &x, &w, "torch.conv2d"), fw::torch_dispatcher(), Env::new()),
            ("torch-nhwc", fw::build_conv("torch", spec, fw::ConvLayout::Nhwc, &x, &w, "torch.conv2d"), fw::torch_dispatcher(), Env::new()),
            ("tf-nchw", fw::build_conv("tf", spec, fw::ConvLayout::Nchw, &x, &w, "tf.conv2d"), fw::tf_dispatcher(), Env::new()),
            ("jax", fw::build_conv("jax", spec, fw::ConvLayout::Nchw, &x, &w, "jax.conv2d"), fw::jax_dispatcher(), Env::new().with("groups", spec.groups.to_string().as_str())),
        ];
        let runs: Vec<SysRun> = candidates
            .into_iter()
            .map(|(n, p, d, e)| SysRun::new(n, d, e, p))
            .collect();
        // compare every pair; report the worst finding of the trial
        let mut worst: Option<(String, String, f64, String)> = None;
        for i in 0..runs.len() {
            for j in (i + 1)..runs.len() {
                let out = mag.audit(&runs[i], &runs[j]);
                if let Some((f, d)) = out.diagnoses.first() {
                    let (wl, el) = match f.wasteful {
                        magneton::detect::Side::A => (&runs[i].label, &runs[j].label),
                        magneton::detect::Side::B => (&runs[j].label, &runs[i].label),
                    };
                    let rec = (
                        wl.clone(),
                        el.clone(),
                        out.e2e_diff_frac,
                        format!("[{}] {}", d.category.name(), d.subject),
                    );
                    if worst.as_ref().map(|w| rec.2 > w.2).unwrap_or(true) {
                        worst = Some(rec);
                    }
                }
            }
        }
        if let Some((wl, el, diff, diag)) = worst {
            discoveries += 1;
            t.row(vec![
                format!(
                    "t{trial}: b{} c{} {}x{} g{}",
                    spec.batch, spec.channels, spec.hw, spec.hw, spec.groups
                ),
                wl,
                el,
                format!("{:.0}%", diff * 100.0),
                diag.chars().take(60).collect(),
            ]);
        }
    }
    println!("{}", t.render());
    println!("{discoveries} trials exposed cross-framework conv inefficiencies (layout-dependent kernels)");
}
