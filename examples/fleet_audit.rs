//! Fleet-audit demo: run a batch of differential audits — every known
//! case of the evaluation suite plus a cross-system LLM serving pair —
//! concurrently over the bounded worker pool, and print the ranked
//! cross-system waste report.
//!
//! ```sh
//! cargo run --release --example fleet_audit [-- --workers 8 --pairs 12]
//! ```

use magneton::cases;
use magneton::coordinator::fleet::FleetAudit;
use magneton::coordinator::SysRun;
use magneton::energy::DeviceSpec;
use magneton::report;
use magneton::systems::llm;
use magneton::systems::SystemId;
use magneton::util::cli::Args;
use magneton::util::table::fmt_joules;
use magneton::util::Prng;

fn main() {
    let args = Args::from_env();
    let mut fleet = FleetAudit::new(DeviceSpec::h200_sim());
    fleet.workers = args.get_parse("workers", fleet.workers);
    let max_pairs: usize = args.get_parse("pairs", 12usize);

    let mut rng = Prng::new(args.get_parse("seed", 2026u64));

    // the paper's known-issue suite, one audit job per case
    for s in cases::known_cases().into_iter().take(max_pairs.saturating_sub(1)) {
        let (a, b) = (s.build)(&mut rng);
        fleet.add_pair(s.id, a, b);
    }

    // plus a cross-system serving pair (Fig 5 style): HF vs vLLM on the
    // same GPT-2-shaped workload
    let params = llm::TransformerParams::new(&mut rng, llm::LlmSpec::gpt2_sim());
    let hf = SysRun::new(
        "mini-hf",
        llm::hf_dispatcher(),
        llm::default_env(SystemId::MiniHf),
        llm::build_llm(&params, &llm::LlmBuildOpts::hf()),
    );
    let vllm = SysRun::new(
        "mini-vllm",
        llm::vllm_dispatcher(),
        llm::default_env(SystemId::MiniVllm),
        llm::build_llm(&params, &llm::LlmBuildOpts::vllm()),
    );
    fleet.add_pair("hf-vs-vllm", hf, vllm);

    println!(
        "auditing {} system pairs over {} workers...\n",
        fleet.len(),
        fleet.workers
    );
    let r = fleet.run();
    print!("{}", report::render_fleet(&r));

    if let Some(top) = r.entries.first() {
        println!(
            "\nworst offender: {} ({} wasted, {} findings)",
            top.name,
            fmt_joules(top.wasted_j),
            top.findings
        );
    }
}
