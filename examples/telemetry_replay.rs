//! Telemetry demo: persist a streaming fleet audit as rotating NDJSON
//! snapshots, then load the directory back and prove the offline replay
//! reproduces the live results **bit-for-bit** — the property that
//! makes snapshots trustworthy evidence for an operator dashboard
//! rather than an approximate log.
//!
//! ```sh
//! cargo run --release --example telemetry_replay [-- --requests 60 --pairs 4]
//! ```

use magneton::coordinator::fleet::StreamFleet;
use magneton::coordinator::SysRun;
use magneton::dispatch::Env;
use magneton::energy::DeviceSpec;
use magneton::report;
use magneton::telemetry::{Replay, SinkConfig};
use magneton::util::cli::Args;
use magneton::util::Prng;
use magneton::workload::{serving_dispatcher, serving_stream_program, ArrivalProcess, ServingStream};

fn main() {
    let args = Args::from_env();
    let requests: usize = args.get_parse("requests", 60usize).max(8);
    let pairs: usize = args.get_parse("pairs", 4usize).max(2);
    let seed: u64 = args.get_parse("seed", 2026u64);
    let dir = std::env::temp_dir().join(format!("magneton-telemetry-demo-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);

    // --- stage 1: a streaming fleet with a snapshot directory ---------
    let spec = ServingStream { requests, ..Default::default() };
    let mut fleet = StreamFleet::new(DeviceSpec::h200_sim());
    fleet.cfg.window_ops = 50;
    fleet.cfg.hop_ops = 50;
    fleet.cfg.ring_cap = 128;
    fleet.arrival = ArrivalProcess::Poisson { rate_hz: 300.0 };
    fleet.ops_per_request = spec.ops_per_request();
    fleet.arrival_seed = seed;
    fleet.snapshot_dir = Some(dir.clone());
    // small rotation bounds so the demo also exercises file cuts
    fleet.sink_cfg = SinkConfig { max_snapshot_bytes: 256 * 1024, rotate_bytes: 16 * 1024 };
    for i in 0..pairs {
        let eff = if i % 2 == 0 { 0.62 } else { 1.0 };
        let mut ra = Prng::new(seed + 1 + i as u64);
        let mut rb = Prng::new(seed + 1 + i as u64);
        fleet.add_pair(
            &format!("serving-{i}"),
            SysRun::new("sys-a", serving_dispatcher(eff), Env::new(), serving_stream_program(&mut ra, &spec)),
            SysRun::new("sys-b", serving_dispatcher(1.0), Env::new(), serving_stream_program(&mut rb, &spec)),
        );
    }
    println!(
        "auditing {} serving pairs x {} ops, snapshots under {} ...\n",
        fleet.len(),
        spec.kernel_ops(),
        dir.display()
    );
    let live = fleet.run();
    print!("{}", report::render_stream_fleet(&live));
    assert_eq!(live.snapshot_errors, 0, "snapshot writes must succeed");

    // --- stage 2: offline replay of the snapshot directory ------------
    let replay = Replay::load(&dir).expect("snapshot directory loads back");
    println!(
        "\nreplayed {} windows, {} summaries, {} ranking(s) from disk",
        replay.windows.len(),
        replay.summaries.len(),
        replay.rankings.len()
    );
    for ranking in &replay.rankings {
        println!("\npersisted fleet ranking (re-rendered offline):");
        print!("{}", report::render_ranking(ranking));
    }

    // --- stage 3: the replay is bit-for-bit, not approximately right --
    for e in &live.entries {
        let s = replay.summary_of(&e.name).expect("summary persisted");
        assert_eq!(
            s.wasted_j.to_bits(),
            e.summary.wasted_j.to_bits(),
            "{}: replayed ledger drifted",
            e.name
        );
        assert_eq!(s.ops, e.summary.ops);
        assert_eq!(s.fingerprint_a, e.summary.fingerprint_a);
    }
    let checked = replay.verify_ranking().expect("persisted ranking verifies");
    assert_eq!(checked, live.entries.len());
    println!(
        "\nreplay verified: {checked} ranking entries reproduce their pair ledgers bit-for-bit"
    );

    let _ = std::fs::remove_dir_all(&dir);
}
