//! DDP imbalance study (paper §2.1 case 2 / Fig 4): train an MLP on two
//! simulated GPUs with uneven data (1.3 : 1) and compare `dist.Join`
//! against hand-written early exit — power timelines + energy totals.
//!
//! ```sh
//! cargo run --release --example ddp_energy
//! ```

use magneton::energy::DeviceSpec;
use magneton::util::table::{fmt_joules, Table};
use magneton::workload::{run_ddp, DdpWorkload, SyncStrategy};

fn main() {
    let dev = DeviceSpec::h200_sim();
    let w = DdpWorkload::paper_setup();
    println!(
        "workload: 2 ranks, batches {}:{} (1.3:1), hidden {}, {} iterations\n",
        w.batch_heavy, w.batch_light, w.hidden, w.iterations
    );

    let join = run_ddp(&dev, &w, SyncStrategy::Join, 7);
    let exit = run_ddp(&dev, &w, SyncStrategy::EarlyExit, 7);

    let mut t = Table::new(vec!["strategy", "rank0 (heavy)", "rank1 (light)", "total", "wall"]);
    for (name, run) in [("dist.Join", &join), ("early-exit", &exit)] {
        t.row(vec![
            name.to_string(),
            fmt_joules(run.traces[0].total_energy()),
            fmt_joules(run.traces[1].total_energy()),
            fmt_joules(run.total_energy_j),
            format!("{:.2} ms", run.wall_us / 1e3),
        ]);
    }
    println!("{}", t.render());
    println!(
        "early exit saves {:.1}% total energy at unchanged wall time\n\
         (the light rank drops to {:.0} W idle instead of spinning at {:.0} W in the join barrier)",
        (1.0 - exit.total_energy_j / join.total_energy_j) * 100.0,
        dev.idle_w,
        0.45 * dev.max_w,
    );
}
