//! Quickstart: differential energy debugging in ~40 lines.
//!
//! Two "systems" compute the same `gelu(x @ w)` — one through a fused
//! efficient kernel, one through an inefficient legacy kernel. Magneton
//! runs both, matches their graphs, detects the waste, and diagnoses
//! the root cause.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use magneton::coordinator::{Magneton, SysRun};
use magneton::dispatch::{Env, KernelChoice, Routine};
use magneton::energy::{ComputeUnit, DeviceSpec};
use magneton::exec::{Dispatcher, Program};
use magneton::graph::{Graph, OpKind};
use magneton::report::render_audit;
use magneton::tensor::Tensor;
use magneton::util::Prng;

fn build_system(label: &str, kernel: &str, efficiency: f64, x: &Tensor, w: &Tensor) -> SysRun {
    let mut g = Graph::new(label);
    let xi = g.add(OpKind::Input, &[], "x");
    let wi = g.add(OpKind::Weight, &[], "w");
    let m = g.add(OpKind::MatMul, &[xi, wi], "linear");
    let a = g.add_attr1(OpKind::Gelu, &[m], "activation", "approx", "tanh");
    g.add(OpKind::Output, &[a], "out");
    let mut prog = Program::new(g);
    prog.feed(0, x.clone());
    prog.feed(1, w.clone());

    let mut disp = Dispatcher::new();
    disp.register(
        "matmul",
        Routine::direct(
            "torch.matmul",
            vec![],
            KernelChoice::new(kernel, ComputeUnit::TensorCore).quality(efficiency, 1.0, 1.0),
        ),
    );
    SysRun::new(label, disp, Env::new(), prog)
}

fn main() {
    // identical workload for both systems
    let mut rng = Prng::new(7);
    let x = Tensor::randn(&mut rng, &[256, 512]);
    let w = Tensor::randn(&mut rng, &[512, 512]);

    let wasteful = build_system("framework-a", "legacy_sgemm_v1", 0.62, &x, &w);
    let efficient = build_system("framework-b", "cutlass_tf32_gemm", 1.0, &x, &w);

    let magneton = Magneton::new(DeviceSpec::h200_sim());
    let outcome = magneton.audit(&wasteful, &efficient);
    println!("{}", render_audit("framework-a", "framework-b", &outcome));
}
