"""L2: GPT-2 transformer-block compute graphs in JAX.

Two semantically equivalent but differently implemented variants — the
same diversity Magneton exploits across real systems:

* ``gpt2_block_a`` (HF-flavoured): separate Q/K/V projections sliced
  from the fused weight, bias fused via addmm-style ``x @ w + b``, and
  the 5-step unfused tanh-GELU decomposition.
* ``gpt2_block_b`` (vLLM-flavoured): one fused QKV projection, split,
  and the fused Pallas GELU kernel (L1).

Both are lowered by ``aot.py`` to HLO text; the Rust integration tests
execute them through PJRT and check them against each other *and*
against the Rust tensor-substrate executor (the numerics cross-check of
DESIGN.md). The weight layout matches
``rust/src/systems/llm.rs::TransformerParams`` exactly.
"""

import jax.numpy as jnp

from .kernels import gelu as gelu_kernel

# Shapes used for the lowered test block. Keep in sync with
# rust/tests/pjrt_reference.rs.
TEST_B, TEST_S, TEST_D, TEST_H, TEST_F = 2, 8, 32, 4, 64


def layernorm(x, g, b, eps=1e-5):
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mean) ** 2, axis=-1, keepdims=True)
    return (x - mean) / jnp.sqrt(var + eps) * g + b


def gelu_tanh_unfused(x):
    """The HF 5-kernel decomposition (same math as the fused kernel)."""
    x3 = x * x * x
    inner = x + 0.044715 * x3
    scaled = 0.7978845608028654 * inner
    t = jnp.tanh(scaled)
    return x * (0.5 * t) + 0.5 * x


def attention_nhd(q, k, v):
    """Scaled dot-product attention over [B, S, H, Dh] (NHD layout)."""
    dh = q.shape[-1]
    scores = jnp.einsum("bshd,bthd->bhst", q, k) / jnp.sqrt(float(dh))
    probs = jnp.exp(scores - jnp.max(scores, axis=-1, keepdims=True))
    probs = probs / jnp.sum(probs, axis=-1, keepdims=True)
    return jnp.einsum("bhst,bthd->bshd", probs, v)


def _block_core(x2d, params, *, fused_qkv: bool, fused_gelu: bool,
                b: int, s: int, d: int, h: int):
    (ln1_g, ln1_b, qkv_w, qkv_b, out_w, out_b,
     ln2_g, ln2_b, ff1_w, ff1_b, ff2_w, ff2_b) = params
    dh = d // h

    ln1 = layernorm(x2d, ln1_g, ln1_b)
    if fused_qkv:
        qkv = ln1 @ qkv_w + qkv_b
        q2, k2, v2 = jnp.split(qkv, 3, axis=1)
    else:
        q2 = ln1 @ qkv_w[:, :d] + qkv_b[:d]
        k2 = ln1 @ qkv_w[:, d:2 * d] + qkv_b[d:2 * d]
        v2 = ln1 @ qkv_w[:, 2 * d:] + qkv_b[2 * d:]
    q = q2.reshape(b, s, h, dh)
    k = k2.reshape(b, s, h, dh)
    v = v2.reshape(b, s, h, dh)
    attn = attention_nhd(q, k, v).reshape(b * s, d)
    res1 = x2d + (attn @ out_w + out_b)

    ln2 = layernorm(res1, ln2_g, ln2_b)
    h1 = ln2 @ ff1_w + ff1_b
    act = gelu_kernel.gelu_tanh(h1) if fused_gelu else gelu_tanh_unfused(h1)
    h2 = act @ ff2_w + ff2_b
    return res1 + h2


def gpt2_block_a(x2d, *params):
    """HF-flavoured block: split projections + unfused GELU."""
    return (_block_core(x2d, params, fused_qkv=False, fused_gelu=False,
                        b=TEST_B, s=TEST_S, d=TEST_D, h=TEST_H),)


def gpt2_block_b(x2d, *params):
    """vLLM-flavoured block: fused QKV + fused Pallas GELU."""
    return (_block_core(x2d, params, fused_qkv=True, fused_gelu=True,
                        b=TEST_B, s=TEST_S, d=TEST_D, h=TEST_H),)


def block_param_shapes(d: int = TEST_D, f: int = TEST_F):
    """Parameter shapes in calling order (mirrors the Rust weight bank)."""
    return [
        (d,), (d,),          # ln1 gamma/beta
        (d, 3 * d), (3 * d,),  # qkv w/b
        (d, d), (d,),        # out proj w/b
        (d,), (d,),          # ln2 gamma/beta
        (d, f), (f,),        # ff1 w/b
        (f, d), (d,),        # ff2 w/b
    ]
