"""AOT lowering: JAX/Pallas -> HLO text artifacts for the Rust runtime.

Emits into ``artifacts/``:

* ``fingerprint_{m}x{n}.hlo.txt`` — the L1 spectral-moment kernel at
  each canonical shape (keep ``FP_SHAPES`` in sync with
  ``rust/src/runtime/mod.rs::FP_SHAPES``),
* ``gpt2_block_a.hlo.txt`` / ``gpt2_block_b.hlo.txt`` — the two L2
  transformer-block variants,
* ``gelu_{m}x{n}.hlo.txt`` — the fused GELU kernel,
* ``manifest.txt`` — human-readable inventory.

HLO *text* is the interchange format, not ``.serialize()``: jax >= 0.5
emits protos with 64-bit instruction ids that the image's xla_extension
0.5.1 rejects; the text parser reassigns ids (see
/opt/xla-example/README.md). Python runs only at build time — the Rust
binary is self-contained once these artifacts exist.
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels import fingerprint, gelu

# Canonical fingerprint shapes (rows x cols). Matches the Rust runtime.
FP_SHAPES = [(32, 256), (64, 1024), (128, 4096)]

# GELU artifact shape (the L2 block's FF activation tile).
GELU_SHAPES = [(model.TEST_B * model.TEST_S, model.TEST_F)]


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe round trip)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_to(path: str, fn, *example_args) -> int:
    lowered = jax.jit(fn).lower(*example_args)
    text = to_hlo_text(lowered)
    with open(path, "w") as f:
        f.write(text)
    return len(text)


def f32(shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out", default="../artifacts", help="output directory")
    args = parser.parse_args()
    out = args.out
    os.makedirs(out, exist_ok=True)
    manifest = []

    # L1 fingerprint kernel at each canonical shape
    for m, n in FP_SHAPES:
        name = f"fingerprint_{m}x{n}"
        size = lower_to(
            os.path.join(out, f"{name}.hlo.txt"),
            fingerprint.fingerprint_fn,
            f32((m, n)),
        )
        manifest.append(f"{name}: input f32[{m},{n}] -> (f32[4],)  [{size} chars]")
        print(f"lowered {name} ({size} chars)")

    # L1 fused GELU kernel
    for m, n in GELU_SHAPES:
        name = f"gelu_{m}x{n}"
        size = lower_to(
            os.path.join(out, f"{name}.hlo.txt"),
            lambda x: (gelu.gelu_tanh(x),),
            f32((m, n)),
        )
        manifest.append(f"{name}: input f32[{m},{n}] -> (f32[{m},{n}],)  [{size} chars]")
        print(f"lowered {name} ({size} chars)")

    # L2 transformer-block variants (shared parameter layout)
    bs = model.TEST_B * model.TEST_S
    x = f32((bs, model.TEST_D))
    params = [f32(s) for s in model.block_param_shapes()]
    for name, fn in [("gpt2_block_a", model.gpt2_block_a), ("gpt2_block_b", model.gpt2_block_b)]:
        size = lower_to(os.path.join(out, f"{name}.hlo.txt"), fn, x, *params)
        manifest.append(
            f"{name}: input f32[{bs},{model.TEST_D}] + 12 params -> (f32[{bs},{model.TEST_D}],)  [{size} chars]"
        )
        print(f"lowered {name} ({size} chars)")

    with open(os.path.join(out, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest) + "\n")
    print(f"wrote {len(manifest)} artifacts to {out}")


if __name__ == "__main__":
    main()
