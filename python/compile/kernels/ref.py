"""Pure-jnp oracles for the Pallas kernels (the correctness signal).

Every Pallas kernel in this package must match its `ref.py` counterpart
under `numpy.testing.assert_allclose` — enforced by
`python/tests/test_kernel.py` (including hypothesis shape sweeps).
"""

import jax.numpy as jnp

SQRT_2_OVER_PI = 0.7978845608028654
GELU_COEF = 0.044715


def matmul_ref(a, b):
    return jnp.matmul(a, b)


def gram_ref(mat):
    return jnp.matmul(mat, mat.T)


def spectral_moments_ref(mat):
    """Reference moments via explicit Gram powers."""
    g = gram_ref(mat)
    g2 = jnp.matmul(g, g)
    g3 = jnp.matmul(g2, g)
    g4 = jnp.matmul(g2, g2)
    return jnp.stack([jnp.trace(g), jnp.trace(g2), jnp.trace(g3), jnp.trace(g4)])


def spectral_moments_svd_ref(mat):
    """Ground-truth moments from the singular values themselves."""
    s = jnp.linalg.svd(mat, compute_uv=False)
    return jnp.stack([jnp.sum(s ** (2 * k)) for k in range(1, 5)])


def gelu_tanh_ref(x):
    inner = SQRT_2_OVER_PI * (x + GELU_COEF * x * x * x)
    return 0.5 * x * (1.0 + jnp.tanh(inner))
