"""L1 Pallas kernel: fused tanh-GELU.

The fused activation the efficient systems use (vLLM's
`gelu_tanh_and_mul`-style single kernel): one HBM read and one write
per element, versus the 5-kernel decomposition HuggingFace ships
(paper S6.3: 77.4% operator-level energy difference). Rows are tiled
into VMEM blocks; the elementwise math runs out of registers.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

SQRT_2_OVER_PI = 0.7978845608028654
GELU_COEF = 0.044715


def _gelu_kernel(x_ref, o_ref):
    x = x_ref[...]
    inner = SQRT_2_OVER_PI * (x + GELU_COEF * x * x * x)
    o_ref[...] = 0.5 * x * (1.0 + jnp.tanh(inner))


def _block(dim: int, target: int) -> int:
    b = min(target, dim)
    while dim % b != 0:
        b //= 2
    return max(b, 1)


def gelu_tanh(x):
    """Fused tanh-GELU over a 2-D activation tile (interpret mode)."""
    m, n = x.shape
    bm = _block(m, 64)
    bn = _block(n, 256)
    return pl.pallas_call(
        _gelu_kernel,
        grid=(m // bm, n // bn),
        in_specs=[pl.BlockSpec((bm, bn), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x)
