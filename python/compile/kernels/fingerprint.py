"""L1 Pallas kernel: spectral-moment tensor fingerprints.

The Magneton coordinator identifies semantically equivalent tensors by
comparing layout-invariant spectra of their matricizations (paper
S4.2). The hot-path invariant is the vector of spectral moments

    m_k = tr((M M^T)^k),  k = 1..4

i.e. the power sums of squared singular values. This module computes
them as two blocked Pallas matmuls (G = M M^T and G2 = G G) plus
in-register reductions:

    m1 = tr(G)        m2 = ||G||_F^2 = tr(G^2)
    m3 = <G2, G>      m4 = ||G2||_F^2 = tr(G^4)

TPU mapping (DESIGN.md "Hardware-Adaptation"): the matricized tensor is
tiled into VMEM blocks via BlockSpec, the Gram product targets the MXU
with f32 accumulation (`preferred_element_type`), and each input element
is read from HBM exactly once per unfolding — the TPU analogue of the
fused-kernel HBM->SRAM argument the paper makes for GELU (S6.3).

`interpret=True` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret-mode lowers to plain HLO which the Rust runtime
loads. Real-TPU perf is estimated in DESIGN.md/EXPERIMENTS.md SPerf.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Moments per unfolding. Keep in sync with rust fingerprint::MOMENT_ORDER.
MOMENT_ORDER = 4


def _matmul_kernel(a_ref, b_ref, o_ref, *, nsteps: int):
    """Blocked matmul with output-block accumulation over the k grid dim.

    The output BlockSpec ignores the k index, so the same VMEM tile is
    revisited across k steps and acts as the accumulator (f32 on the
    MXU via preferred_element_type).
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )


def _block(dim: int, target: int) -> int:
    """Largest power-of-two block <= target that divides dim."""
    b = min(target, dim)
    while dim % b != 0:
        b //= 2
    return max(b, 1)


def matmul(a, b, bm: int = 32, bn: int = 32, bk: int = 128):
    """Blocked Pallas matmul `a @ b` (f32, interpret mode)."""
    m, ka = a.shape
    kb, n = b.shape
    assert ka == kb, f"inner dims {ka} vs {kb}"
    bm = _block(m, bm)
    bn = _block(n, bn)
    bk = _block(ka, bk)
    grid = (m // bm, n // bn, ka // bk)
    kernel = functools.partial(_matmul_kernel, nsteps=grid[2])
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(a, b)


def gram(mat):
    """G = M M^T via the blocked Pallas matmul."""
    return matmul(mat, mat.T)


def spectral_moments(mat):
    """The 4-vector [tr(G), tr(G^2), tr(G^3), tr(G^4)], G = M M^T."""
    g = gram(mat)
    g2 = matmul(g, g)
    m1 = jnp.trace(g)
    m2 = jnp.sum(g * g)  # tr(G^2): G symmetric
    m3 = jnp.sum(g2 * g)  # tr(G^3) = <G^2, G^T> = <G^2, G>
    m4 = jnp.sum(g2 * g2)  # tr(G^4) = ||G^2||_F^2
    return jnp.stack([m1, m2, m3, m4])


def fingerprint_fn(mat):
    """AOT entrypoint: returns a 1-tuple (the Rust loader unpacks it)."""
    return (spectral_moments(mat),)
