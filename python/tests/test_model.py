"""L2 model checks: the two block variants are semantically equivalent,
shapes line up with the Rust weight bank, and AOT lowering produces
loadable HLO text.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import aot, model

jax.config.update("jax_platform_name", "cpu")


def make_params(seed=0, scale=None):
    rng = np.random.default_rng(seed)
    d = model.TEST_D
    if scale is None:
        scale = 1.0 / np.sqrt(d)
    params = []
    for shape in model.block_param_shapes():
        params.append(jnp.asarray(rng.standard_normal(shape, dtype=np.float32) * scale))
    # LN gains near 1
    params[0] = jnp.abs(params[0]) * 0.1 + 1.0
    params[6] = jnp.abs(params[6]) * 0.1 + 1.0
    return params


def make_x(seed=1):
    rng = np.random.default_rng(seed)
    bs = model.TEST_B * model.TEST_S
    return jnp.asarray(rng.standard_normal((bs, model.TEST_D), dtype=np.float32))


class TestBlockVariants:
    def test_variants_agree(self):
        x = make_x()
        params = make_params()
        (a,) = model.gpt2_block_a(x, *params)
        (b,) = model.gpt2_block_b(x, *params)
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-5)

    def test_output_shape(self):
        x = make_x()
        (a,) = model.gpt2_block_a(x, *make_params())
        assert a.shape == (model.TEST_B * model.TEST_S, model.TEST_D)

    def test_attention_rows_mix_sequence(self):
        # the block must not be position-independent: shuffling the
        # sequence changes outputs (attention mixes positions)
        x = make_x()
        params = make_params()
        (a,) = model.gpt2_block_b(x, *params)
        xs = jnp.concatenate([x[model.TEST_S // 2:], x[: model.TEST_S // 2]])
        (b,) = model.gpt2_block_b(xs, *params)
        assert not np.allclose(np.asarray(a), np.asarray(b), atol=1e-4)

    def test_layernorm_normalises(self):
        x = make_x() * 100.0
        g = jnp.ones((model.TEST_D,))
        b = jnp.zeros((model.TEST_D,))
        ln = model.layernorm(x, g, b)
        np.testing.assert_allclose(np.asarray(jnp.mean(ln, axis=-1)), 0.0, atol=1e-4)
        np.testing.assert_allclose(np.asarray(jnp.var(ln, axis=-1)), 1.0, atol=1e-2)

    def test_unfused_gelu_matches_kernel_ref(self):
        from compile.kernels import ref
        x = make_x()
        np.testing.assert_allclose(
            model.gelu_tanh_unfused(x), ref.gelu_tanh_ref(x), rtol=1e-5, atol=1e-6
        )


class TestAotLowering:
    def test_hlo_text_emitted(self, tmp_path):
        x = jax.ShapeDtypeStruct((model.TEST_B * model.TEST_S, model.TEST_D), jnp.float32)
        params = [jax.ShapeDtypeStruct(s, jnp.float32) for s in model.block_param_shapes()]
        n = aot.lower_to(str(tmp_path / "blk.hlo.txt"), model.gpt2_block_b, x, *params)
        assert n > 1000
        text = (tmp_path / "blk.hlo.txt").read_text()
        assert text.startswith("HloModule")
        assert "f32[" in text

    def test_fingerprint_artifact_shapes_match_rust(self):
        # FP_SHAPES must mirror rust/src/runtime/mod.rs
        assert aot.FP_SHAPES == [(32, 256), (64, 1024), (128, 4096)]

    @pytest.mark.parametrize("m,n", [(32, 256)])
    def test_fingerprint_lowering(self, tmp_path, m, n):
        from compile.kernels import fingerprint
        size = aot.lower_to(
            str(tmp_path / "fp.hlo.txt"),
            fingerprint.fingerprint_fn,
            jax.ShapeDtypeStruct((m, n), jnp.float32),
        )
        assert size > 500
