"""L1 kernel correctness: Pallas vs pure-jnp oracles.

The CORE correctness signal for the compile path: every Pallas kernel
must match ref.py under assert_allclose, across a hypothesis sweep of
shapes (the kernels must handle any block-divisible or ragged shape via
the block-shrinking helper).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import fingerprint, gelu, ref

jax.config.update("jax_platform_name", "cpu")


def randn(rng, shape, scale=1.0):
    return jnp.asarray(rng.standard_normal(shape, dtype=np.float32) * scale)


# ---------------------------------------------------------------------
# blocked matmul
# ---------------------------------------------------------------------

class TestMatmul:
    @pytest.mark.parametrize("m,k,n", [(32, 256, 32), (64, 1024, 64), (8, 16, 24), (128, 128, 128)])
    def test_matches_ref(self, m, k, n):
        rng = np.random.default_rng(1)
        a = randn(rng, (m, k))
        b = randn(rng, (k, n))
        np.testing.assert_allclose(
            fingerprint.matmul(a, b), ref.matmul_ref(a, b), rtol=1e-5, atol=1e-4
        )

    def test_identity(self):
        eye = jnp.eye(32, dtype=jnp.float32)
        a = jnp.arange(32 * 32, dtype=jnp.float32).reshape(32, 32)
        np.testing.assert_allclose(fingerprint.matmul(a, eye), a, rtol=1e-6)

    @settings(max_examples=20, deadline=None)
    @given(
        m=st.integers(1, 48),
        k=st.integers(1, 96),
        n=st.integers(1, 48),
        seed=st.integers(0, 2**31),
    )
    def test_hypothesis_shapes(self, m, k, n, seed):
        rng = np.random.default_rng(seed)
        a = randn(rng, (m, k))
        b = randn(rng, (k, n))
        np.testing.assert_allclose(
            fingerprint.matmul(a, b), ref.matmul_ref(a, b), rtol=1e-4, atol=1e-3
        )


# ---------------------------------------------------------------------
# gram + spectral moments
# ---------------------------------------------------------------------

class TestFingerprint:
    @pytest.mark.parametrize("m,n", [(32, 256), (64, 1024), (16, 80)])
    def test_gram_matches_ref(self, m, n):
        rng = np.random.default_rng(2)
        mat = randn(rng, (m, n), scale=0.1)
        np.testing.assert_allclose(
            fingerprint.gram(mat), ref.gram_ref(mat), rtol=1e-4, atol=1e-4
        )

    @pytest.mark.parametrize("m,n", [(32, 256), (16, 64), (8, 40)])
    def test_moments_match_gram_powers(self, m, n):
        rng = np.random.default_rng(3)
        mat = randn(rng, (m, n), scale=0.1)
        got = fingerprint.spectral_moments(mat)
        want = ref.spectral_moments_ref(mat)
        np.testing.assert_allclose(got, want, rtol=1e-4)

    def test_moments_match_svd_ground_truth(self):
        rng = np.random.default_rng(4)
        mat = randn(rng, (16, 96), scale=0.1)
        got = fingerprint.spectral_moments(mat)
        want = ref.spectral_moments_svd_ref(mat)
        np.testing.assert_allclose(got, want, rtol=1e-3)

    def test_zero_padding_invariance(self):
        # the Rust runtime pads tensors into canonical shapes; zero
        # rows/cols must not change any moment
        rng = np.random.default_rng(5)
        mat = randn(rng, (10, 70), scale=0.1)
        padded = jnp.zeros((32, 256), jnp.float32).at[:10, :70].set(mat)
        np.testing.assert_allclose(
            fingerprint.spectral_moments(mat),
            fingerprint.spectral_moments(padded),
            rtol=1e-4,
        )

    def test_transpose_invariance(self):
        # sigma(M) == sigma(M^T): moments agree across orientation
        rng = np.random.default_rng(6)
        mat = randn(rng, (12, 40), scale=0.2)
        m_a = fingerprint.spectral_moments(mat)
        m_b = fingerprint.spectral_moments(mat.T)
        np.testing.assert_allclose(m_a, m_b, rtol=1e-4)

    def test_column_permutation_invariance(self):
        # reordering columns is a layout change; the Gram matrix (and
        # so every moment) is unchanged
        rng = np.random.default_rng(9)
        mat = np.asarray(randn(rng, (8, 32), scale=0.3))
        perm = rng.permutation(32)
        m_a = fingerprint.spectral_moments(jnp.asarray(mat))
        m_b = fingerprint.spectral_moments(jnp.asarray(mat[:, perm]))
        np.testing.assert_allclose(m_a, m_b, rtol=1e-4)

    @settings(max_examples=15, deadline=None)
    @given(m=st.integers(2, 32), n=st.integers(2, 128), seed=st.integers(0, 2**31))
    def test_hypothesis_moments(self, m, n, seed):
        rng = np.random.default_rng(seed)
        mat = randn(rng, (m, n), scale=0.2)
        got = fingerprint.spectral_moments(mat)
        want = ref.spectral_moments_ref(mat)
        np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-5)

    def test_moments_positive(self):
        rng = np.random.default_rng(7)
        mat = randn(rng, (8, 32))
        m = np.asarray(fingerprint.spectral_moments(mat))
        assert (m > 0).all()
        # Cauchy-Schwarz-ish ordering on normalised moments
        assert m[1] <= m[0] ** 2 + 1e-3


# ---------------------------------------------------------------------
# fused GELU
# ---------------------------------------------------------------------

class TestGelu:
    @pytest.mark.parametrize("m,n", [(16, 64), (64, 256), (7, 33)])
    def test_matches_ref(self, m, n):
        rng = np.random.default_rng(8)
        x = randn(rng, (m, n))
        np.testing.assert_allclose(
            gelu.gelu_tanh(x), ref.gelu_tanh_ref(x), rtol=1e-5, atol=1e-6
        )

    def test_known_values(self):
        x = jnp.zeros((4, 4), jnp.float32)
        np.testing.assert_allclose(gelu.gelu_tanh(x), x, atol=1e-7)
        # gelu(large) ~ identity, gelu(-large) ~ 0
        big = jnp.full((4, 4), 10.0, jnp.float32)
        np.testing.assert_allclose(gelu.gelu_tanh(big), big, rtol=1e-4)
        np.testing.assert_allclose(gelu.gelu_tanh(-big), jnp.zeros((4, 4)), atol=1e-4)

    @settings(max_examples=15, deadline=None)
    @given(m=st.integers(1, 64), n=st.integers(1, 128), seed=st.integers(0, 2**31))
    def test_hypothesis_shapes(self, m, n, seed):
        rng = np.random.default_rng(seed)
        x = randn(rng, (m, n), scale=2.0)
        np.testing.assert_allclose(
            gelu.gelu_tanh(x), ref.gelu_tanh_ref(x), rtol=1e-4, atol=1e-5
        )
